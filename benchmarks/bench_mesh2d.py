"""2-D mesh scaling of the model-sharded flat engine (repro.core.sharded).

The 2-D ('agents', 'model') lowering (launch.mesh.make_fed_mesh) block-
shards the flat (n_agents, D) buffer's agent dim over A devices AND
column-shards each agent row's D dim over M devices, so per-device state
scales as 1/(A·M) — the memory axis that lets billion-parameter agents fit.
This benchmark measures, on 8 forced host devices (the multi-device CI
recipe), a fused H-step FedDec round over the mesh grid
(A, M) ∈ {(1,1), (8,1), (4,2), (2,4), (1,8)} for the dense / sparse /
pallas gossip paths:

  * measured per-device shard bytes — asserted EQUAL to the analytic
    ``n/A · D/M · param_bytes`` (the 1/(A·M) scaling law, exact, not
    approximate: the engine pins P('agents', 'model') on every 2-D leaf);
  * the full mesh2d_cost_model byte columns (agent-axis gossip bytes on
    D/M-wide slices, model-axis loss/matmul collective bytes, server psum
    bytes) recorded per row for the regression guard to recompute;
  * wall-clock per fused round (CPU loopback — not ICI-representative;
    the transferable evidence is the byte columns, same caveat as
    bench_sharded).

Every (A, M) cell is first checked against the single-device flat engine's
trajectory to 1e-5 (the conformance tolerance), so the numbers always
describe a correct lowering.

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_mesh2d.json (consumed by CI's perf-regression
guard and docs/PERFORMANCE.md).

Run:  PYTHONPATH=src python -m benchmarks.bench_mesh2d [--smoke]

Re-executes itself in a forced-8-device subprocess so the parent's jax
device state is never touched (same pattern as bench_sharded).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

N_DEVICES = 8

MESH_GRID = ((1, 1), (8, 1), (4, 2), (2, 4), (1, 8))
IMPLS = ("dense", "sparse", "pallas")


def main(smoke: bool = False) -> None:
    """Respawn into a forced-8-device subprocess and stream its output."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={N_DEVICES} "
                        + env.get("XLA_FLAGS", "")).strip()
    env.setdefault("PYTHONPATH", os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    cmd = [sys.executable, "-m", "benchmarks.bench_mesh2d", "--child"]
    if smoke:
        cmd.append("--smoke")
    res = subprocess.run(cmd, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError(f"bench_mesh2d child failed ({res.returncode})")


def _child_main(smoke: bool) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import common
    from repro.core import flat as flat_lib
    from repro.core import sharded, topology as topo
    from repro.core.feddec import FedDecConfig
    from repro.core.mixing import MixingDistribution
    from repro.launch import analysis
    from repro.launch.mesh import make_fed_mesh

    assert len(jax.devices()) >= N_DEVICES, "forced host devices missing"

    if smoke:
        warmup, iters = 1, 3
        n, d, h = 8, 1 << 10, 2
    else:
        warmup, iters = 2, 5
        n, d, h = 32, 1 << 14, 4

    graph = topo.ring_graph(n, k=2)
    md = MixingDistribution(graph, scheme="metropolis")
    spec = flat_lib.make_flat_spec(jnp.zeros(d))

    def grad_fn(p, batch, key):
        del key
        return 0.5 * jnp.sum((p - batch) ** 2), p - batch

    def lr_fn(t):
        return jnp.asarray(0.05, jnp.float32)

    batches = jax.random.normal(jax.random.key(3), (h, n, d), jnp.float32)
    key = jax.random.key(4)

    rows = []
    n_equiv_checked = 0
    for impl in IMPLS:
        cfg = FedDecConfig(mixing=md, h=h, k=2, gossip_impl=impl)
        # the single-device flat reference this impl's cells must match
        ref_round = flat_lib.make_flat_feddec_round(
            cfg, spec, grad_fn, lr_fn, donate=False)
        ref_state, ref_m = ref_round(
            flat_lib.init_flat_state(spec, jnp.zeros(d), n), batches, key)
        ref_flat = np.asarray(ref_state.flat)
        ref_loss = np.asarray(ref_m["loss"])

        for a, m in MESH_GRID:
            if n % a or d % m:
                continue
            mesh = make_fed_mesh(a, m)
            cut = sharded.cut_edge_stats(graph, a)
            model = analysis.mesh2d_cost_model(
                n_agents=n, d=d, n_agent_shards=a, n_model_shards=m,
                num_halo_rounds=cut["num_halo_rounds"], param_bytes=4)[impl]
            round_fn = sharded.make_sharded_feddec_round(
                cfg, spec, grad_fn, lr_fn, mesh, donate=False,
                model_axis="model")
            state0 = sharded.shard_flat_state(
                flat_lib.init_flat_state(spec, jnp.zeros(d), n), mesh,
                model_axis="model")
            out_state, out_m = round_fn(state0, batches, key)
            np.testing.assert_allclose(np.asarray(out_state.flat), ref_flat,
                                       atol=1e-5, rtol=1e-5)
            np.testing.assert_allclose(np.asarray(out_m["loss"]), ref_loss,
                                       atol=1e-5, rtol=1e-5)
            n_equiv_checked += 1
            shard_bytes = out_state.flat.addressable_shards[0].data.nbytes
            us = common.time_fn(lambda: round_fn(state0, batches, key),
                                warmup=warmup, iters=iters)
            row = {"impl": impl, "n_agents": n, "d": d, "h": h,
                   "n_agent_shards": a, "n_model_shards": m,
                   "agents_per_device": n // a,
                   "us_per_round": round(us, 1),
                   "us_per_step": round(us / h, 1),
                   "shard_bytes_measured": int(shard_bytes),
                   "state_bytes_per_device": model["state_bytes_per_device"],
                   "gossip_collective_bytes":
                       model["gossip_collective_bytes"],
                   "model_collective_bytes": model["model_collective_bytes"],
                   "server_bytes_per_round": model["server_bytes_per_round"],
                   "num_halo_rounds": cut["num_halo_rounds"]}
            assert shard_bytes == model["state_bytes_per_device"], row
            rows.append(row)
            common.emit(
                f"mesh2d_{impl}_a{a}_m{m}", us,
                f"shard_bytes={shard_bytes};"
                f"model_coll={model['model_collective_bytes']:.0f}")

    base_bytes = n * d * 4
    acceptance = {
        "per_device_bytes_scaling": {
            f"{r['n_agent_shards']}x{r['n_model_shards']}":
                r["shard_bytes_measured"] for r in rows
            if r["impl"] == "dense"},
        "am_way_scaling_exact": all(
            r["shard_bytes_measured"]
            * r["n_agent_shards"] * r["n_model_shards"] == base_bytes
            for r in rows),
        "equivalence_checked_vs_flat": n_equiv_checked == len(rows)
        and bool(rows),
        "note": ("CPU host-platform devices: collectives run over loopback "
                 "memory, so wall-clock is not ICI-representative; the "
                 "transferable evidence is the exact 1/(A*M) per-device "
                 "byte scaling and the mesh2d_cost_model byte columns "
                 "(agent-axis gossip on D/M slices, model-axis loss "
                 "all-reduce), verified against the committed formulas by "
                 "check_regression.check_mesh2d_doc"),
    }
    out = {"workload": "fused H-step FedDec round, flat (n, D) buffer "
                       "sharded P('agents', 'model') on make_fed_mesh(A, M)",
           "backend": jax.default_backend(), "smoke": smoke,
           "devices": N_DEVICES, "rows": rows, "acceptance": acceptance}
    name = "BENCH_mesh2d.smoke.json" if smoke else "BENCH_mesh2d.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv("bench_mesh2d.csv", list(rows[0].keys()),
                     [tuple(r.values()) for r in rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / few iterations for CI")
    p.add_argument("--child", action="store_true",
                   help="internal: run the benchmark body (assumes the "
                        "forced-device XLA flag is already set)")
    args = p.parse_args()
    if args.child:
        _child_main(smoke=args.smoke)
    else:
        print("name,us_per_call,derived")
        main(smoke=args.smoke)
