"""Population engine at scale — streaming cost, overlap, and bit-identity.

Three sections, all against repro.core.population (cohort-sampled FedDec
with a host-resident memmap store and double-buffered h2d/d2h streaming):

* **scale rows** — n_total ∈ {1e4, 1e5, 1e6} at a fixed cohort (256) and
  the paper's linreg D=25: µs/round of the overlapped pipeline plus every
  column of ``launch.analysis.population_cost_model``.  The acceptance
  invariant is that ``peak_device_bytes`` is IDENTICAL across all rows —
  device residency is two (cohort, D) buffers + two cohort ELL tables,
  with **no n_total term** (the whole point of the engine; uniform
  sampling is Floyd's O(cohort), so the host side is n_total-free too).
* **overlap** — the double-buffered schedule vs the synchronous baseline
  (``overlap=False``: block after every round) at a host/device-balanced
  shape, with the measured per-stage decomposition.  Three numbers:
  ``speedup_measured`` (wall-clock sync/overlap), ``device_stage_ms``
  (the blocked round on prepared inputs), ``host_stage_ms`` (sync minus
  device — gather, subgraph Metropolis + ELL build, upload, write-back).
  ``speedup_pipeline_bound = sync / max(host, device)`` is what the
  pipeline delivers when host and device are distinct execution resources
  (any accelerator, or a multi-core host); it is computed from measured
  stage times, not a model.  On a single-CPU runner (``host_cpus == 1``,
  recorded) XLA "device" compute and numpy host work share one core, so
  wall-clock overlap is physically bounded at ~1.0× there — the guard
  (benchmarks.check_regression.check_population_doc) therefore enforces
  the ≥1.2× floor on the bound always and on the measured ratio only
  when the recording machine had host_cpus > 1.
* **equivalence** — with ``n_total == cohort_size`` the uniform cohort is
  the identity slice, the induced subgraph is the full graph, and the ELL
  tables match ``gossip.make_sparse_gossip`` entry-for-entry: the
  population trajectory must be **bit-identical** to the flat engine with
  ``gossip_impl='sparse'`` (``max_abs_err == 0.0``, pinned).

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_population.json (smoke runs write
BENCH_population.smoke.json so the committed baseline is never clobbered).

Run:  PYTHONPATH=src python -m benchmarks.bench_population [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feddec, flat as flat_lib
from repro.core import population as pop
from repro.core import topology as topo
from repro.core.flat import FlatFedState
from repro.core.mixing import MixingDistribution
from repro.data import linreg
from repro.launch import analysis

M_ROWS = 10
RING_K = 2                  # ring-lattice graph → max degree 4 at any n
SCALE_COHORT, SCALE_D, SCALE_H, K = 256, 25, 10, 2
# host/device-balanced overlap shape (n_total ≫ cohort² keeps the
# conflict-drain rate ~cohort²/n_total low so the pipeline stays full)
OVERLAP = {"n_total": 262144, "cohort": 128, "d": 2048, "h": 8, "m": 4}
OVERLAP_SMOKE = {"n_total": 65536, "cohort": 64, "d": 512, "h": 8, "m": 2}


def _host_cpus() -> int:
    try:
        return len(os.sched_getaffinity(0))
    except AttributeError:  # pragma: no cover - non-Linux
        return os.cpu_count() or 1


def _device_batch_fn(c: int, d: int, h: int, m: int):
    """(round_idx, ids) → (xb, yb) sampled ON DEVICE from a fixed dataset.

    Batch leading dims (H, c, ...) as the engine requires.  jnp sampling
    dispatches asynchronously, so the host cost of the data stage is just
    the dispatch — the benchmark's host stage is the streaming work itself
    (gather / subgraph / ELL build / upload / write-back).
    """
    kx, ky, kd = jax.random.split(jax.random.key(5), 3)
    x = jax.random.normal(kx, (c, M_ROWS, d)) * 0.25
    y = jax.random.normal(ky, (c, M_ROWS))

    def samp_one(k):
        idx = jax.random.randint(k, (c, m), 0, M_ROWS)
        return (jnp.take_along_axis(x, idx[..., None], axis=1),
                jnp.take_along_axis(y, idx, axis=1))

    samp = jax.jit(lambda k: jax.vmap(samp_one)(jax.random.split(k, h)))

    def batch_fn(round_idx, ids):
        return samp(jax.random.fold_in(kd, round_idx))

    return batch_fn


def _make_engine(n_total: int, c: int, d: int, h: int,
                 seed: int = 0) -> pop.PopulationEngine:
    graph = topo.ring_graph_csr(n_total, RING_K)
    spec = pop.PopulationSpec(n_total, c, max_degree=2 * RING_K, seed=seed)
    fspec = flat_lib.make_flat_spec(jnp.zeros(d))
    lr = lambda t: jnp.float32(1e-3)  # noqa: E731
    return pop.PopulationEngine(spec, fspec, linreg.make_grad_fn(M_ROWS),
                                lr, graph, h=h, k=K,
                                row_init=np.zeros(d, np.float32))


def bench_scale(n_total: int, *, rounds: int) -> dict:
    """One n_total row: µs/round (overlapped) + the exact cost model."""
    eng = _make_engine(n_total, SCALE_COHORT, SCALE_D, SCALE_H)
    batch_fn = _device_batch_fn(SCALE_COHORT, SCALE_D, SCALE_H, m=1)
    eng.run(2, batch_fn, jax.random.key(0))        # compile + warm
    t0 = time.perf_counter()
    out = eng.run(rounds, batch_fn, jax.random.key(0))
    us = (time.perf_counter() - t0) / rounds * 1e6
    model = analysis.population_cost_model(
        n_total=n_total, cohort_size=SCALE_COHORT, d=SCALE_D,
        max_degree=2 * RING_K, h=SCALE_H, param_bytes=4)
    row = {"us_per_round": round(us, 1), "drains": int(out["drains"]),
           "rounds": rounds, **model}
    common.emit(f"population_n{n_total}", us,
                f"peak_device_bytes={model['peak_device_bytes']};"
                f"drains={out['drains']}")
    return row


def bench_overlap(shape: dict, *, rounds: int) -> dict:
    """Sync vs overlapped wall time + the measured stage decomposition."""
    n_total, c, d, h, m = (shape["n_total"], shape["cohort"], shape["d"],
                           shape["h"], shape["m"])
    eng = _make_engine(n_total, c, d, h)
    batch_fn = _device_batch_fn(c, d, h, m)
    key = jax.random.key(0)
    eng.run(2, batch_fn, key)                      # compile + warm
    eng.run(2, batch_fn, key, overlap=False)

    # device stage alone: the blocked fused round on prepared inputs
    # (state re-uploaded per call — the round donates its input buffer)
    ids, flat, mix, _ = eng._prepare(eng._sample(), batch_fn, 0)
    host_rows = np.asarray(jax.device_get(flat))
    dev_ts = []
    for _ in range(rounds):
        st = FlatFedState(flat=jax.device_put(host_rows),
                          step=jnp.asarray(1, jnp.int32))
        batches = batch_fn(0, ids)
        jax.block_until_ready((st.flat, batches))
        t0 = time.perf_counter()
        new_state, _ = eng._round(st, batches, key, mix)
        jax.block_until_ready(new_state.flat)
        dev_ts.append(time.perf_counter() - t0)
    dev_ms = sorted(dev_ts)[len(dev_ts) // 2] * 1e3

    t0 = time.perf_counter()
    eng.run(rounds, batch_fn, key, overlap=False)
    sync_ms = (time.perf_counter() - t0) / rounds * 1e3
    t0 = time.perf_counter()
    out = eng.run(rounds, batch_fn, key, overlap=True)
    ov_ms = (time.perf_counter() - t0) / rounds * 1e3

    host_ms = max(sync_ms - dev_ms, 1e-9)
    measured = sync_ms / ov_ms
    bound = sync_ms / max(dev_ms, host_ms)
    rec = {**shape, "rounds": rounds, "drains": int(out["drains"]),
           "host_cpus": _host_cpus(),
           "sync_ms_per_round": round(sync_ms, 2),
           "overlap_ms_per_round": round(ov_ms, 2),
           "device_stage_ms": round(dev_ms, 2),
           "host_stage_ms": round(host_ms, 2),
           "speedup_measured": round(measured, 3),
           "speedup_pipeline_bound": round(bound, 3)}
    common.emit(f"population_overlap_c{c}_d{d}", ov_ms * 1e3,
                f"sync_ms={sync_ms:.2f};measured={measured:.2f}x;"
                f"bound={bound:.2f}x")
    return rec


def bench_equivalence(*, rounds: int = 3) -> dict:
    """n_total == cohort: population trajectory ≡ flat sparse, bitwise."""
    n, d, h = 12, 25, 4
    problem = linreg.make_problem(n=n, m_rows=M_ROWS, d=d, seed=0)
    graph = topo.geographic_graph(n, 0.5, seed=1)
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    lr = lambda t: jnp.float32(1e-3)  # noqa: E731
    fspec = flat_lib.make_flat_spec(jnp.zeros(d))
    key = jax.random.key(7)
    per_round = [
        jax.block_until_ready(jax.vmap(
            lambda k: linreg.sample_minibatch(problem, k, m=2))(
            jax.random.split(jax.random.fold_in(jax.random.key(3), r), h)))
        for r in range(rounds)]

    # flat engine, ELL sparse gossip on the full graph
    fcfg = feddec.FedDecConfig(
        mixing=MixingDistribution(graph, p_fail=0.0, scheme="metropolis"),
        h=h, k=3, gossip_impl="sparse")
    flat_round = flat_lib.make_flat_feddec_round(fcfg, fspec, grad_fn, lr,
                                                 donate=False)
    st = flat_lib.init_flat_state(fspec, jnp.zeros(d), n)
    for r in range(rounds):
        st, _ = flat_round(st, per_round[r], key)
    ref = np.asarray(st.flat)

    # population engine over the same graph, cohort == population
    spec = pop.PopulationSpec(n, n, max_degree=int(graph.degrees.max()))
    eng = pop.PopulationEngine(spec, fspec, grad_fn, lr,
                               topo.csr_from_graph(graph), h=h, k=3,
                               row_init=np.zeros(d, np.float32))
    eng.run(rounds, lambda r, ids: per_round[r], key)
    got = eng.store.gather(np.arange(n))

    max_err = float(np.abs(got - ref).max())
    bit = bool(np.array_equal(got, ref))
    common.emit("population_equivalence", 0.0,
                f"max_abs_err={max_err:.1e};bit_identical={bit}")
    return {"n_total": n, "cohort_size": n, "d": d, "h": h,
            "rounds": rounds, "max_abs_err": max_err, "bit_identical": bit}


def main(smoke: bool = False) -> None:
    if smoke:
        grid, rounds, ov_shape, ov_rounds = ((10**4, 10**5), 4,
                                             OVERLAP_SMOKE, 6)
    else:
        grid, rounds, ov_shape, ov_rounds = ((10**4, 10**5, 10**6), 12,
                                             OVERLAP, 16)

    rows = [bench_scale(n, rounds=rounds) for n in grid]
    overlap = bench_overlap(ov_shape, rounds=ov_rounds)
    equivalence = bench_equivalence()

    peaks = {r["peak_device_bytes"] for r in rows}
    acceptance = {
        "peak_device_bytes_flat": len(peaks) == 1,
        "peak_device_bytes": rows[0]["peak_device_bytes"],
        "max_n_total": max(grid),
        "overlap_speedup_measured": overlap["speedup_measured"],
        "overlap_speedup_pipeline_bound": overlap["speedup_pipeline_bound"],
        "host_cpus": overlap["host_cpus"],
        "cohort_bit_identical": equivalence["bit_identical"],
        "note": ("peak_device_bytes has no n_total term (two (cohort, D) "
                 "buffers + two ELL tables — the streaming invariant); the "
                 "overlap floor applies to speedup_pipeline_bound (measured "
                 "stage times, host and device as distinct resources) and "
                 "additionally to speedup_measured when host_cpus > 1 — a "
                 "single-CPU runner time-slices XLA compute and numpy host "
                 "work, capping measured wall-clock overlap at ~1.0x; "
                 "bit-identity: n_total == cohort makes the uniform cohort "
                 "the identity slice and the subgraph ELL tables equal to "
                 "gossip.make_sparse_gossip's, so the trajectory matches "
                 "the flat sparse engine exactly")}
    out = {"workload": "cohort-sampled FedDec population engine (linreg)",
           "backend": jax.default_backend(), "smoke": smoke,
           "rows": rows, "overlap": overlap, "equivalence": equivalence,
           "acceptance": acceptance}
    name = "BENCH_population.smoke.json" if smoke else "BENCH_population.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv("bench_population.csv", list(rows[0].keys()),
                     [tuple(r.values()) for r in rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="smaller n_total grid / fewer rounds for CI")
    args = p.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
