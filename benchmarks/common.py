"""Shared benchmark utilities: timing, CSV emission, result paths."""

from __future__ import annotations

import os
import time
from typing import Callable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")


def ensure_results_dir() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def write_csv(name: str, header: list[str], rows: list[tuple]) -> str:
    path = os.path.join(ensure_results_dir(), name)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (blocks on jax outputs)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The run.py contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.1f},{derived}")
