"""Shared benchmark utilities: timing, CSV emission, result paths, and the
linreg sweep-lattice setup shared by the figure scripts.

The figure scripts (fig2_alpha / fig4_convergence / theory_check) all drive
the same §4 linear-regression workload through the batched sweep engine
(repro.core.sweep): they build per-run key chains, stack per-cell mixing
setups, and reduce per-run final losses the same way.  Those pieces live
here so each script only describes its lattice.
"""

from __future__ import annotations

import argparse
import os
import time
from typing import Callable

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "results",
                           "benchmarks")


def ensure_results_dir() -> str:
    os.makedirs(RESULTS_DIR, exist_ok=True)
    return RESULTS_DIR


def write_csv(name: str, header: list[str], rows: list[tuple]) -> str:
    path = os.path.join(ensure_results_dir(), name)
    with open(path, "w") as f:
        f.write(",".join(header) + "\n")
        for r in rows:
            f.write(",".join(str(x) for x in r) + "\n")
    return path


def time_fn(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median wall-time per call in microseconds (blocks on jax outputs)."""
    import jax
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str) -> None:
    """The run.py contract: ``name,us_per_call,derived`` CSV lines."""
    print(f"{name},{us_per_call:.1f},{derived}")


def figure_arg_parser(description: str, *, t_steps: int | None = None,
                      seeds: int | None = None) -> argparse.ArgumentParser:
    """Shared --seeds/--t-steps/--smoke CLI for the figure scripts (they
    previously hardcoded module constants).  ``--smoke`` maps to each
    script's reduced CI settings (what run.py --quick passes)."""
    p = argparse.ArgumentParser(description=description)
    if t_steps is not None:
        p.add_argument("--t-steps", type=int, default=t_steps,
                       help=f"iterations T (default {t_steps})")
    if seeds is not None:
        p.add_argument("--seeds", type=int, default=seeds,
                       help=f"independent runs per cell (default {seeds})")
    p.add_argument("--smoke", action="store_true",
                   help="reduced T/seeds for CI smoke runs")
    return p


# ---------------------------------------------------------------------------
# Linreg sweep-lattice setup (shared by fig2/fig4/theory_check)
# ---------------------------------------------------------------------------


def paper_lr_fn(problem, h: int):
    """The Theorem-1 stepsize for a linreg cell: η_t = 2/(μ(γ(H)+t))."""
    from repro.core import theory
    return theory.paper_stepsize(
        problem.mu, theory.gamma(problem.l_smooth, problem.mu, h))


def paper_gamma(problem, h: int) -> float:
    from repro.core import theory
    return theory.gamma(problem.l_smooth, problem.mu, h)


def round_key_chains(seed_keys, n_rounds: int):
    """The figure drivers' per-round key split, precomputed per run.

    Reproduces ``key, kb, ks = jax.random.split(key, 3)`` chained from each
    run's seed key for ``n_rounds`` rounds.  Returns ``(kbs, kss)``, each a
    (R, n_rounds) key array: kb feeds minibatch sampling, ks is the round
    key handed to the executor.  Chains are prefixes of longer chains, so
    runs with fewer rounds (larger H) just use their leading columns.
    """
    import jax

    def chain(seed_key):
        def body(k, _):
            k, kb, ks = jax.random.split(k, 3)
            return k, (kb, ks)
        _, out = jax.lax.scan(body, seed_key, length=n_rounds)
        return out

    return jax.vmap(chain)(seed_keys)


def per_step_keys(kss, h_arr, t_steps: int):
    """(R, max_rounds) round keys → (T, R) per-step keys for the sweep
    round executor (``per_step_keys=True``): step s of run r runs inside
    round s // h_r and folds that round's key with the carried counter."""
    import jax.numpy as jnp
    r = kss.shape[0]
    rounds = jnp.arange(t_steps)[:, None] // jnp.asarray(h_arr)[None, :]
    return kss[jnp.arange(r)[None, :], rounds]


def lattice_minibatch_indices(kbs, h_arr, t_steps: int, n_agents: int,
                              m_batch: int, m_rows: int):
    """Per-step minibatch row indices (T, R, n, m) for the whole lattice.

    Reproduces each run's per-round draw
    ``jax.random.randint(kb, (h, n, m), 0, m_rows)`` — one (h, n, m) block
    per round key, concatenated along the step axis — grouped by H so every
    run's rows are bit-identical to the per-run driver's.
    """
    import jax
    import jax.numpy as jnp
    import numpy as np

    h_arr = np.asarray(h_arr)
    r = h_arr.shape[0]
    idx_all = np.zeros((t_steps, r, n_agents, m_batch), dtype=np.int32)
    for h in np.unique(h_arr):
        runs = np.flatnonzero(h_arr == h)
        n_rounds = t_steps // int(h)
        draw = jax.jit(jax.vmap(jax.vmap(
            lambda k: jax.random.randint(
                k, (int(h), n_agents, m_batch), 0, m_rows))))
        blocks = draw(kbs[jnp.asarray(runs), :n_rounds])
        idx = np.asarray(blocks).reshape(len(runs), t_steps, n_agents,
                                         m_batch)
        idx_all[:, runs] = idx.transpose(1, 0, 2, 3)
    return idx_all


def sweep_minibatch_gather(problem):
    """(R, n, m) row indices → the per-agent (xb, yb) minibatch pytree the
    sweep step consumes; the batched form of the figure drivers'
    ``take_along_axis`` gather."""
    import jax.numpy as jnp
    xs = jnp.asarray(problem.x)
    ys = jnp.asarray(problem.y)

    def gather(idx):
        xb = jnp.take_along_axis(xs[None], idx[..., None], axis=2)
        yb = jnp.take_along_axis(ys[None], idx, axis=2)
        return xb, yb

    return gather


def sweep_suboptimality(problem):
    """(R, n, d) sweep buffer → per-run f(z̄) − f* (the Fig. 4 curve)."""
    import jax.numpy as jnp
    xs = jnp.asarray(problem.x)
    ys = jnp.asarray(problem.y)

    def subopt(flat3):
        zbar = flat3.mean(axis=1)                       # (R, d)
        res = jnp.einsum("imd,rd->rim", xs, zbar) - ys[None]
        return jnp.mean(jnp.sum(res * res, axis=-1),
                        axis=1) / problem.m_rows - problem.f_star

    return subopt
