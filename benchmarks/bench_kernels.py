"""Kernel micro-benchmarks: XLA reference path timings + Pallas validation.

On this CPU container the Pallas kernels execute in interpret mode (Python),
so wall-times are reported for the jitted XLA oracle paths (what actually
runs off-TPU) while the Pallas kernels are re-validated for correctness and
their *structural* VMEM/roofline numbers derived from the BlockSpecs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops, ref


def bench_flash_attention():
    b, s, h, kv, hd = 2, 1024, 8, 2, 64
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, kv, hd), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, kv, hd), jnp.float32)
    fn = jax.jit(lambda q, k, v: ref.flash_attention_ref(q, k, v, window=256))
    us = common.time_fn(fn, q, k, v)
    out = ops.flash_attention(q, k, v, window=256)
    err = float(jnp.abs(out - fn(q, k, v)).max())
    flops = 4 * b * h * s * min(256, s) * hd  # windowed attention
    common.emit("kernel_flash_attention_xla_ref", us,
                f"pallas_err={err:.1e};roofline_flops={flops:.2e}")


def bench_ssd():
    b, s, h, p, n = 1, 2048, 8, 64, 128
    ks = jax.random.split(jax.random.key(1), 5)
    x = jax.random.normal(ks[0], (b, s, h, p))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, s, h)))
    a = -jnp.exp(jax.random.normal(ks[2], (h,)) * 0.3)
    bb = jax.random.normal(ks[3], (b, s, n))
    c = jax.random.normal(ks[4], (b, s, n))
    fn = jax.jit(lambda *args: ref.ssd_chunked_ref(*args, chunk=256)[0])
    us = common.time_fn(fn, x, dt, a, bb, c)
    y, _ = ops.ssd_scan(x, dt, a, bb, c, chunk=256)
    err = float(jnp.abs(y - fn(x, dt, a, bb, c)).max())
    common.emit("kernel_ssd_scan_xla_ref", us, f"pallas_err={err:.1e}")


def bench_rglru():
    b, s, w = 2, 2048, 512
    ka, kb = jax.random.split(jax.random.key(2))
    a = jax.nn.sigmoid(jax.random.normal(ka, (b, s, w)))
    bx = jax.random.normal(kb, (b, s, w))
    fn = jax.jit(lambda a, bx: ref.rglru_assoc_ref(a, bx)[0])
    us = common.time_fn(fn, a, bx)
    h, _ = ops.rglru_scan(a, bx)
    err = float(jnp.abs(h - fn(a, bx)).max())
    common.emit("kernel_rglru_scan_xla_ref", us, f"pallas_err={err:.1e}")


def bench_gossip():
    n, d = 32, 1 << 20
    kw, kx = jax.random.split(jax.random.key(3))
    w = jax.random.uniform(kw, (n, n))
    w = w / w.sum(1, keepdims=True)
    x = jax.random.normal(kx, (n, d), jnp.float32)
    fn = jax.jit(ref.gossip_mix_ref)
    us = common.time_fn(fn, w, x)
    y = ops.gossip_mix(w, x)
    err = float(jnp.abs(y - fn(w, x)).max())
    gbps = (2 * n * d * 4) / (us / 1e6) / 1e9
    common.emit("kernel_gossip_mix_xla_ref", us,
                f"pallas_err={err:.1e};stream={gbps:.1f}GB/s")


def main() -> None:
    bench_flash_attention()
    bench_ssd()
    bench_rglru()
    bench_gossip()


if __name__ == "__main__":
    main()
