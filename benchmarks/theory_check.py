"""Theorem 1 validation: the bound dominates the measured trajectory.

All constants are computed from the problem instance (L, μ, Γ exactly; G²
and σ̄² estimated by sampling gradients along the trajectory, then inflated
2× as a safe upper bound, since Assumption 1.3 requires a uniform bound).
Checks:

  B1  E[f(z̄^t)] − f(z*) ≤ bound(t) for all recorded t;
  B2  the FedDec B-constant is below the FedAvg C-constant (αH vs H²) for
      the measured |λ̂₂| and H.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feddec, theory, topology as topo
from repro.core.mixing import MixingDistribution
from repro.data import linreg

N, T, H, K = 20, 3000, 10, 2


def run_experiment():
    jax.config.update("jax_enable_x64", True)
    problem = linreg.make_problem(n=N, seed=0)
    graph = topo.geographic_graph(N, 0.5, seed=1)
    md = MixingDistribution(graph, scheme="laplacian")
    fcfg = feddec.FedDecConfig(mixing=md, h=H, k=K)
    gam = theory.gamma(problem.l_smooth, problem.mu, H)
    lr = theory.paper_stepsize(problem.mu, gam)
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    # fused executor: H steps per dispatch, per-step f(z̄^t) − f* recorded
    # on-device via metrics_fn
    round_fn = feddec.make_feddec_round(
        fcfg, grad_fn, lr, donate=False,
        metrics_fn=lambda s: {"subopt": problem.suboptimality(s.params)})

    state = feddec.init_state(jnp.zeros(problem.d), N)
    key = jax.random.key(0)
    sub, g2_max, sig2 = [], 0.0, []
    xs, ys = jnp.asarray(problem.x), jnp.asarray(problem.y)
    assert T % H == 0, (T, H)
    for r in range(T // H):
        # estimate G² and σ̄² along the trajectory (every 50 steps)
        if (r * H) % 50 == 0:
            key, ke = jax.random.split(key)
            batch = linreg.sample_minibatch(problem, ke, m=1)
            zb = state.params
            gfull = 2 * jnp.einsum("imd,im->id",
                                   xs, jnp.einsum("imd,id->im", xs, zb) - ys
                                   ) / problem.m_rows
            gb = jax.vmap(lambda z, b_: grad_fn(z, b_, None)[1])(
                zb, (batch[0], batch[1]))
            g2_max = max(g2_max, float((gb ** 2).sum(-1).max()))
            sig2.append(float(((gb - gfull) ** 2).sum(-1).mean()))
        key, kb = jax.random.split(key)
        batches = jax.vmap(
            lambda k: linreg.sample_minibatch(problem, k, m=1))(
            jax.random.split(kb, H))
        state, metrics = round_fn(state, batches, jax.random.key(1))
        sub.extend(np.asarray(metrics["subopt"]).tolist())

    lam_hat = md.lambda2_hat()
    inp = theory.TheoremInputs(
        l_smooth=problem.l_smooth, mu=problem.mu,
        g2=2.0 * g2_max, sigma_bar2=2.0 * float(np.mean(sig2)),
        gamma_heterogeneity=problem.gamma_heterogeneity, n=N, k=K, h=H,
        lambda2_hat=lam_hat,
        dist0_sq=float((problem.z_star ** 2).sum()))
    bound = theory.theorem1_curve(inp, T)
    return np.asarray(sub), bound, inp


def main() -> None:
    t0 = time.perf_counter()
    sub, bound, inp = run_experiment()
    ts = np.arange(1, len(sub) + 1)
    rows = list(zip(ts[::25], sub[::25], bound[::25]))
    common.write_csv("theory_check.csv", ["t", "empirical", "bound"], rows)

    dominated = bool((sub <= bound[:len(sub)]).all())
    print(f"# B1 bound dominates trajectory for all t: "
          f"{'PASS' if dominated else 'FAIL'} "
          f"(max ratio {float((sub / bound[:len(sub)]).max()):.3f})")
    a = theory.alpha(inp.lambda2_hat)
    b_dec = theory.bound_constant_B(
        k=K, alpha_val=a, h=H, g2=inp.g2, l_smooth=inp.l_smooth,
        gamma_heterogeneity=inp.gamma_heterogeneity,
        sigma_bar2=inp.sigma_bar2, n=N)
    c_avg = theory.fedavg_bound_constant(
        k=K, h=H, g2=inp.g2, l_smooth=inp.l_smooth,
        gamma_heterogeneity=inp.gamma_heterogeneity,
        sigma_bar2=inp.sigma_bar2, n=N)
    print(f"# B2 B_feddec={b_dec:.3e} < C_fedavg={c_avg:.3e} "
          f"(α={a:.2f} vs H={H}): {'PASS' if b_dec < c_avg else 'FAIL'}")
    n_pass = int(dominated) + int(b_dec < c_avg)
    common.emit("theory_check", (time.perf_counter() - t0) * 1e6,
                f"claims_pass={n_pass}/2")


if __name__ == "__main__":
    main()
