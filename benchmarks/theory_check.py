"""Theorem 1 validation: the bound dominates the measured trajectory.

All constants are computed from the problem instance (L, μ, Γ exactly; G²
and σ̄² estimated by sampling gradients along the trajectory, then inflated
2× as a safe upper bound, since Assumption 1.3 requires a uniform bound).

The trajectory runs on the batched sweep engine (repro.core.sweep, R=1 —
the degenerate lattice): the pre-sweep driver dispatched one fused round
per server window (T/H dispatches) with host round-trips in between; here
the whole T-step trajectory is **one compiled scan** that records the
per-step suboptimality *and* the per-step iterate on-device, and the G²/σ̄²
estimation replays against the recorded iterates afterwards on the host —
same estimator, same key chain, zero mid-run dispatches.

Checks:

  B1  E[f(z̄^t)] − f(z*) ≤ bound(t) for all recorded t;
  B2  the FedDec B-constant is below the FedAvg C-constant (αH vs H²) for
      the measured |λ̂₂| and H.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feddec, flat as flat_lib, sweep, theory, \
    topology as topo
from repro.core.mixing import MixingDistribution
from repro.data import linreg

N, T, H, K = 20, 3000, 10, 2


def run_experiment(t_steps: int = T):
    jax.config.update("jax_enable_x64", True)
    problem = linreg.make_problem(n=N, seed=0)
    graph = topo.geographic_graph(N, 0.5, seed=1)
    md = MixingDistribution(graph, scheme="laplacian")
    fcfg = feddec.FedDecConfig(mixing=md, h=H, k=K)
    lr = common.paper_lr_fn(problem, H)
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    xs, ys = jnp.asarray(problem.x), jnp.asarray(problem.y)
    assert t_steps % H == 0, (t_steps, H)
    n_rounds = t_steps // H

    # replay the pre-sweep driver's host key chain: per round, one optional
    # estimation-batch split (every 50 steps) then the round's batch split
    key = jax.random.key(0)
    ke_rounds: dict[int, jax.Array] = {}
    kb_list = []
    for r in range(n_rounds):
        if (r * H) % 50 == 0:
            key, ke = jax.random.split(key)
            ke_rounds[r] = ke
        key, kb = jax.random.split(key)
        kb_list.append(kb)
    # per-step minibatch keys: round r contributes split(kb_r, H)
    step_batch_keys = jnp.concatenate(
        [jax.random.split(kb, H) for kb in kb_list])

    plan = sweep.make_sweep_plan([fcfg])
    spec = flat_lib.make_flat_spec(jnp.zeros(problem.d, xs.dtype))
    step = sweep.make_sweep_feddec_step(plan, spec, grad_fn, lr, jit=False)
    run_keys = jnp.stack([jax.random.key(1)])  # the driver's constant key

    @jax.jit
    def run_all():
        state0 = sweep.init_sweep_state(plan, spec, jnp.zeros(problem.d))

        def body(state, bk):
            xb, yb = linreg.sample_minibatch(problem, bk, m=1)
            state, _ = step(state, (xb[None], yb[None]), run_keys)
            return state, (problem.suboptimality(state.flat[0]),
                           state.flat[0])

        _, (sub, z_rec) = jax.lax.scan(body, state0, step_batch_keys)
        return sub, z_rec

    sub, z_rec = run_all()  # one compile, one device program
    sub, z_rec = np.asarray(sub), np.asarray(z_rec)

    # G²/σ̄² estimation along the recorded trajectory (every 50 steps),
    # identical to the pre-sweep driver's: zb is the pre-round iterate
    g2_max, sig2 = 0.0, []
    z0 = np.zeros((N, problem.d))
    for r, ke in ke_rounds.items():
        zb = jnp.asarray(z0 if r == 0 else z_rec[r * H - 1])
        batch = linreg.sample_minibatch(problem, ke, m=1)
        gfull = 2 * jnp.einsum("imd,im->id",
                               xs, jnp.einsum("imd,id->im", xs, zb) - ys
                               ) / problem.m_rows
        gb = jax.vmap(lambda z, b_: grad_fn(z, b_, None)[1])(
            zb, (batch[0], batch[1]))
        g2_max = max(g2_max, float((gb ** 2).sum(-1).max()))
        sig2.append(float(((gb - gfull) ** 2).sum(-1).mean()))

    lam_hat = md.lambda2_hat()
    inp = theory.TheoremInputs(
        l_smooth=problem.l_smooth, mu=problem.mu,
        g2=2.0 * g2_max, sigma_bar2=2.0 * float(np.mean(sig2)),
        gamma_heterogeneity=problem.gamma_heterogeneity, n=N, k=K, h=H,
        lambda2_hat=lam_hat,
        dist0_sq=float((problem.z_star ** 2).sum()))
    bound = theory.theorem1_curve(inp, t_steps)
    return sub, bound, inp


def main(t_steps: int = T) -> None:
    t0 = time.perf_counter()
    sub, bound, inp = run_experiment(t_steps)
    ts = np.arange(1, len(sub) + 1)
    rows = list(zip(ts[::25], sub[::25], bound[::25]))
    common.write_csv("theory_check.csv", ["t", "empirical", "bound"], rows)

    dominated = bool((sub <= bound[:len(sub)]).all())
    print(f"# B1 bound dominates trajectory for all t: "
          f"{'PASS' if dominated else 'FAIL'} "
          f"(max ratio {float((sub / bound[:len(sub)]).max()):.3f})")
    a = theory.alpha(inp.lambda2_hat)
    b_dec = theory.bound_constant_B(
        k=K, alpha_val=a, h=H, g2=inp.g2, l_smooth=inp.l_smooth,
        gamma_heterogeneity=inp.gamma_heterogeneity,
        sigma_bar2=inp.sigma_bar2, n=N)
    c_avg = theory.fedavg_bound_constant(
        k=K, h=H, g2=inp.g2, l_smooth=inp.l_smooth,
        gamma_heterogeneity=inp.gamma_heterogeneity,
        sigma_bar2=inp.sigma_bar2, n=N)
    print(f"# B2 B_feddec={b_dec:.3e} < C_fedavg={c_avg:.3e} "
          f"(α={a:.2f} vs H={H}): {'PASS' if b_dec < c_avg else 'FAIL'}")
    n_pass = int(dominated) + int(b_dec < c_avg)
    common.emit("theory_check", (time.perf_counter() - t0) * 1e6,
                f"claims_pass={n_pass}/2")


if __name__ == "__main__":
    p = common.figure_arg_parser(__doc__, t_steps=T)
    args = p.parse_args()
    main(t_steps=1500 if args.smoke else args.t_steps)
