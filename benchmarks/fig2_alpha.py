"""Paper Fig. 2: α = |λ̂₂|/(1−|λ̂₂|) as a function of |λ̂₂|.

Also validates Lemma 3's consensus-contraction prediction empirically: for a
fixed W, repeated gossip shrinks the consensus error by ≈|λ₂|² per round,
and the random-failure case matches the Monte-Carlo |λ̂₂| = λ₂(E[WWᵀ]).
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import gossip, theory, topology as topo
from repro.core.mixing import MixingDistribution


def run_curve():
    xs = np.linspace(0.0, 0.98, 50)
    return [(float(x), theory.alpha(float(x))) for x in xs]


def empirical_contraction(p_fail: float = 0.0, rounds: int = 30,
                          seed: int = 0):
    """Measured per-round consensus contraction vs |λ̂₂|."""
    g = topo.geographic_graph(20, 0.5, seed=3)
    md = MixingDistribution(g, p_fail=p_fail,
                            scheme="metropolis" if p_fail else "laplacian")
    lam_hat = md.lambda2_hat(jax.random.key(1), 4096)
    x = jax.random.normal(jax.random.key(seed), (20, 64), jnp.float64) \
        if jax.config.jax_enable_x64 else \
        jax.random.normal(jax.random.key(seed), (20, 64))

    def err(z):
        return float(((z - z.mean(0)) ** 2).sum())

    e_prev, ratios = err(x), []
    key = jax.random.key(7)
    for _ in range(rounds):
        key, kw = jax.random.split(key)
        x = gossip.gossip_mix_dense(md.sample(kw), x)
        e = err(x)
        if e_prev > 1e-25:
            ratios.append(e / e_prev)
        e_prev = e
    return lam_hat, float(np.mean(ratios[:10]))


def main() -> None:
    t0 = time.perf_counter()
    rows = [(x, a) for x, a in run_curve()]
    common.write_csv("fig2_alpha.csv", ["lambda2_hat", "alpha"], rows)

    lam_fixed, ratio_fixed = empirical_contraction(0.0)
    lam_fail, ratio_fail = empirical_contraction(0.5)
    ok_fixed = ratio_fixed <= lam_fixed * 1.15
    ok_fail = ratio_fail <= lam_fail * 1.25
    print(f"# F1 fixed W: contraction/round {ratio_fixed:.3f} ≤ |λ̂₂| "
          f"{lam_fixed:.3f} (Lemma 3): {'PASS' if ok_fixed else 'FAIL'}")
    print(f"# F2 p_fail=0.5: contraction {ratio_fail:.3f} ≲ |λ̂₂| "
          f"{lam_fail:.3f}: {'PASS' if ok_fail else 'FAIL'}")
    n_pass = int(ok_fixed) + int(ok_fail)
    common.emit("fig2_alpha", (time.perf_counter() - t0) * 1e6,
                f"claims_pass={n_pass}/2")


if __name__ == "__main__":
    main()
