"""Paper Fig. 2: α = |λ̂₂|/(1−|λ̂₂|) as a function of |λ̂₂|.

Also validates Lemma 3's consensus-contraction prediction empirically: for a
fixed W, repeated gossip shrinks the consensus error by ≈|λ₂|² per round,
and the random-failure case matches the Monte-Carlo |λ̂₂| = λ₂(E[WWᵀ]).

Both contraction experiments (fixed W and p_fail = 0.5) run **batched in
one compiled scan** on the sweep engine's per-run mixing sampler
(repro.core.sweep.make_sweep_w_sampler): the pre-sweep driver dispatched
one sample + one mix + one host sync per round per case (120 dispatches);
this is one device program for the whole figure.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feddec, sweep, theory, topology as topo
from repro.core.mixing import MixingDistribution

P_FAILS = (0.0, 0.5)


def run_curve():
    xs = np.linspace(0.0, 0.98, 50)
    return [(float(x), theory.alpha(float(x))) for x in xs]


def empirical_contractions(rounds: int = 30, seed: int = 0):
    """Measured per-round consensus contraction vs |λ̂₂|, all cases batched.

    Returns {p_fail: (lam_hat, mean contraction ratio over the first 10
    rounds)} — the same estimator as the per-case loops this replaces (the
    key chain, the W draws, and the error recursion are reproduced per run;
    only the host round-trips are gone).
    """
    g = topo.geographic_graph(20, 0.5, seed=3)
    mds = [MixingDistribution(g, p_fail=p,
                              scheme="metropolis" if p else "laplacian")
           for p in P_FAILS]
    lam_hats = [md.lambda2_hat(jax.random.key(1), 4096) for md in mds]

    plan = sweep.make_sweep_plan(
        [feddec.FedDecConfig(mixing=md) for md in mds])
    sampler = sweep.make_sweep_w_sampler(plan)
    dtype = jnp.float64 if jax.config.jax_enable_x64 else jnp.float32
    x0 = jax.random.normal(jax.random.key(seed), (20, 64), dtype)
    x0 = jnp.broadcast_to(x0[None], (len(mds),) + x0.shape)

    def err(x):
        return ((x - x.mean(axis=1, keepdims=True)) ** 2).sum(axis=(1, 2))

    @jax.jit
    def run(x0):
        def body(carry, _):
            x, key = carry
            key, kw = jax.random.split(key)
            w = sampler(jnp.broadcast_to(kw[None], (len(mds),)))
            x = jnp.einsum("rij,rjd->rid", w.astype(x.dtype), x,
                           precision=jax.lax.Precision.HIGHEST)
            return (x, key), err(x)
        (_, _), errors = jax.lax.scan(body, (x0, jax.random.key(7)),
                                      length=rounds)
        return err(x0), errors

    e0, errors = run(x0)
    e0, errors = np.asarray(e0), np.asarray(errors)     # (R,), (rounds, R)
    out = {}
    for r, p in enumerate(P_FAILS):
        e_prev, ratios = e0[r], []
        for e in errors[:, r]:
            if e_prev > 1e-25:
                ratios.append(e / e_prev)
            e_prev = e
        out[p] = (lam_hats[r], float(np.mean(ratios[:10])))
    return out


def main() -> None:
    t0 = time.perf_counter()
    rows = [(x, a) for x, a in run_curve()]
    common.write_csv("fig2_alpha.csv", ["lambda2_hat", "alpha"], rows)

    con = empirical_contractions()
    lam_fixed, ratio_fixed = con[0.0]
    lam_fail, ratio_fail = con[0.5]
    ok_fixed = ratio_fixed <= lam_fixed * 1.15
    ok_fail = ratio_fail <= lam_fail * 1.25
    print(f"# F1 fixed W: contraction/round {ratio_fixed:.3f} ≤ |λ̂₂| "
          f"{lam_fixed:.3f} (Lemma 3): {'PASS' if ok_fixed else 'FAIL'}")
    print(f"# F2 p_fail=0.5: contraction {ratio_fail:.3f} ≲ |λ̂₂| "
          f"{lam_fail:.3f}: {'PASS' if ok_fail else 'FAIL'}")
    n_pass = int(ok_fixed) + int(ok_fail)
    common.emit("fig2_alpha", (time.perf_counter() - t0) * 1e6,
                f"claims_pass={n_pass}/2")


if __name__ == "__main__":
    p = common.figure_arg_parser(__doc__)
    p.parse_args()  # --smoke accepted for CLI uniformity; already cheap
    main()
