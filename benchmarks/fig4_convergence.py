"""Paper Fig. 4: FedDec vs FedAvg on heterogeneous linear regression.

Exact §4 setup: n=20 agents, d=25, M=10 rows/agent, c_i = 2^i heterogeneity,
minibatch m=1, K=2 partial participation, T=5000 iterations, stepsize
η_t = 2/(μ(γ+t)) from Theorem 1, geographic graphs r ∈ {0.35, 0.5}
(Fig. 3), H ∈ {10, 100}, Laplacian (best-constant) mixing weights,
averaged over 10 independent runs.

The whole figure is **one compiled program on the batched sweep engine**
(repro.core.sweep): the full (graph × H × alg × seed) lattice — 80 runs at
the paper's settings — is stacked into a single (R, n, d) buffer and scanned
through all T steps in one ``jax.jit``, with per-run mixing matrices,
per-run H (the heterogeneous server-round period lives in the step body),
per-run Theorem-1 stepsizes, and the per-step suboptimality f(z̄^t) − f*
recorded on-device.  Each run's key chain reproduces the pre-sweep per-cell
driver exactly (per-round ``split(key, 3)`` re-keying via the executor's
``per_step_keys`` path), so run slices — and the emitted CSV — are
unchanged from the per-cell drivers'; float64 (c_20 = 2^20 squares into
~1e12, f32 would lose the suboptimality signal).

Validated claims (asserted when run under pytest / run.py):
  C1  FedDec reaches lower suboptimality than FedAvg in all four settings;
  C2  the FedDec/FedAvg gap grows with H (horizontal comparison in Fig. 4);
  C3  the gap grows with connectivity (vertical comparison: r=0.5 > r=0.35).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feddec, flat as flat_lib, sweep, topology as topo
from repro.core.fedavg import FedAvgConfig
from repro.core.mixing import MixingDistribution
from repro.data import linreg

N, D, M_ROWS, T, K, M_BATCH = 20, 25, 10, 5000, 2, 1
SEEDS = 10
H_VALUES = (10, 100)


def _lattice(problem, graphs: dict, seeds: int):
    """The figure's (graph × H × alg) cells × seeds, in CSV row order."""
    cells, cfgs, gammas = [], [], []
    for gname, graph in graphs.items():
        for h in H_VALUES:
            for alg in ("feddec", "fedavg"):
                cells.append((gname, h, alg))
                if alg == "feddec":
                    fcfg = feddec.FedDecConfig(
                        mixing=MixingDistribution(graph, scheme="laplacian"),
                        h=h, k=K)
                else:
                    fcfg = FedAvgConfig(N, h=h, k=K)
                cfgs.extend([fcfg] * seeds)
                gammas.extend([common.paper_gamma(problem, h)] * seeds)
    return cells, cfgs, np.asarray(gammas)


def run_experiment(t_steps: int = T, seeds: int = SEEDS,
                   record_every: int = 50):
    jax.config.update("jax_enable_x64", True)
    problem = linreg.make_problem(n=N, m_rows=M_ROWS, d=D, seed=0)
    graphs = {"sparse_r0.35": topo.geographic_graph(N, 0.35, seed=1),
              "dense_r0.50": topo.geographic_graph(N, 0.50, seed=1)}
    cells, cfgs, gammas = _lattice(problem, graphs, seeds)
    plan = sweep.make_sweep_plan(cfgs)
    spec = flat_lib.make_flat_spec(jnp.zeros(D, jnp.asarray(problem.x).dtype))
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    lr_fn = lambda t: 2.0 / (problem.mu * (gammas + t))  # noqa: E731

    # every cell re-keys each H-step server window from the same per-seed
    # chain (key, kb, ks = split(key, 3)) the per-cell drivers used; runs
    # with larger H consume a prefix of the same chain
    assert all(t_steps % h == 0 for h in H_VALUES), (t_steps, H_VALUES)
    seed_keys = jax.random.split(jax.random.key(42), seeds)
    run_seed_keys = jnp.concatenate([seed_keys] * len(cells))
    max_rounds = t_steps // min(H_VALUES)
    kbs, kss = common.round_key_chains(run_seed_keys, max_rounds)
    step_keys = common.per_step_keys(kss, plan.h, t_steps)
    idx_all = jnp.asarray(common.lattice_minibatch_indices(
        kbs, plan.h, t_steps, N, M_BATCH, M_ROWS))

    gather = common.sweep_minibatch_gather(problem)
    subopt = common.sweep_suboptimality(problem)
    step = sweep.make_sweep_feddec_step(plan, spec, grad_fn, lr_fn,
                                        jit=False)

    @jax.jit
    def run_all():
        state0 = sweep.init_sweep_state(plan, spec, jnp.zeros(D))

        def body(state, xs):
            idx_t, keys_t = xs
            state, _ = step(state, gather(idx_t), keys_t)
            return state, subopt(state.flat)

        final_state, sub = jax.lax.scan(body, state0, (idx_all, step_keys))
        return sub[::record_every], subopt(final_state.flat)

    sub_rec, last = run_all()  # one compile, one device program
    sub_rec = np.asarray(sub_rec)                       # (T/rec, R)
    last = np.asarray(last)                             # (R,)

    rows, finals = [], {}
    for c, (gname, h, alg) in enumerate(cells):
        cols = slice(c * seeds, (c + 1) * seeds)
        mean_curve = sub_rec[:, cols].mean(axis=1)
        finals[(gname, h, alg)] = float(last[cols].mean())
        for i, v in enumerate(mean_curve):
            rows.append((gname, h, alg, i * record_every, float(v)))
    return rows, finals


def validate(finals: dict) -> list[str]:
    checks = []
    for g in ("sparse_r0.35", "dense_r0.50"):
        for h in (10, 100):
            dec, avg = finals[(g, h, "feddec")], finals[(g, h, "fedavg")]
            checks.append(
                f"C1 {g} H={h}: feddec {dec:.3e} < fedavg {avg:.3e}: "
                f"{'PASS' if dec < avg else 'FAIL'}")
    for g in ("sparse_r0.35", "dense_r0.50"):
        gain10 = finals[(g, 10, "fedavg")] / finals[(g, 10, "feddec")]
        gain100 = finals[(g, 100, "fedavg")] / finals[(g, 100, "feddec")]
        checks.append(f"C2 {g}: gain(H=100)={gain100:.2f} > "
                      f"gain(H=10)={gain10:.2f}: "
                      f"{'PASS' if gain100 > gain10 else 'FAIL'}")
    for h in (10, 100):
        gs = finals[("sparse_r0.35", h, "fedavg")] / \
            finals[("sparse_r0.35", h, "feddec")]
        gd = finals[("dense_r0.50", h, "fedavg")] / \
            finals[("dense_r0.50", h, "feddec")]
        checks.append(f"C3 H={h}: dense gain {gd:.2f} > sparse gain "
                      f"{gs:.2f}: {'PASS' if gd > gs else 'FAIL'}")
    return checks


def main(t_steps: int = T, seeds: int = SEEDS) -> None:
    import time
    t0 = time.perf_counter()
    rows, finals = run_experiment(t_steps, seeds)
    common.write_csv("fig4_convergence.csv",
                     ["graph", "H", "alg", "t", "suboptimality"], rows)
    checks = validate(finals)
    for c in checks:
        print("#", c)
    n_pass = sum("PASS" in c for c in checks)
    common.emit("fig4_feddec_vs_fedavg",
                (time.perf_counter() - t0) * 1e6,
                f"claims_pass={n_pass}/{len(checks)}")


if __name__ == "__main__":
    p = common.figure_arg_parser(__doc__, t_steps=T, seeds=SEEDS)
    args = p.parse_args()
    if args.smoke:
        args.t_steps, args.seeds = 1500, 3
    main(t_steps=args.t_steps, seeds=args.seeds)
