"""Paper Fig. 4: FedDec vs FedAvg on heterogeneous linear regression.

Exact §4 setup: n=20 agents, d=25, M=10 rows/agent, c_i = 2^i heterogeneity,
minibatch m=1, K=2 partial participation, T=5000 iterations, stepsize
η_t = 2/(μ(γ+t)) from Theorem 1, geographic graphs r ∈ {0.35, 0.5}
(Fig. 3), H ∈ {10, 100}, Laplacian (best-constant) mixing weights,
averaged over 10 independent runs.

Whole sweep runs on the **fused round executor**
(core.feddec.make_feddec_round): an outer ``lax.scan`` over server-round
windows wraps the fused H-step inner scan, with the per-step suboptimality
f(z̄^t) − f* recorded on-device via the executor's ``metrics_fn`` hook — the
entire (graph, H, alg) cell is still one jitted computation, vmapped over the
10 seeds; float64 (c_20 = 2^20 squares into ~1e12, f32 would lose the
suboptimality signal).

Validated claims (asserted when run under pytest / run.py):
  C1  FedDec reaches lower suboptimality than FedAvg in all four settings;
  C2  the FedDec/FedAvg gap grows with H (horizontal comparison in Fig. 4);
  C3  the gap grows with connectivity (vertical comparison: r=0.5 > r=0.35).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feddec, theory, topology as topo
from repro.core.fedavg import FedAvgConfig
from repro.core.mixing import MixingDistribution
from repro.data import linreg

N, D, M_ROWS, T, K, M_BATCH = 20, 25, 10, 5000, 2, 1
SEEDS = 10


def _make_runner(problem: linreg.LinRegProblem, fcfg: feddec.FedDecConfig,
                 t_steps: int, record_every: int):
    lr = theory.paper_stepsize(
        problem.mu, theory.gamma(problem.l_smooth, problem.mu, fcfg.h))
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    xs = jnp.asarray(problem.x)
    ys = jnp.asarray(problem.y)
    f_star = problem.f_star

    def subopt(params):
        zbar = params.mean(axis=0)
        r = jnp.einsum("imd,d->im", xs, zbar) - ys
        return jnp.mean(jnp.sum(r * r, axis=-1)) / problem.m_rows - f_star

    # the fused executor: one inner lax.scan per server-round window of H
    # steps, suboptimality recorded per step on-device via metrics_fn
    round_fn = feddec.make_feddec_round(
        fcfg, grad_fn, lr, jit=False, donate=False,
        metrics_fn=lambda s: {"subopt": subopt(s.params)})
    h = fcfg.h
    assert t_steps % h == 0, (t_steps, h)
    n_rounds = t_steps // h

    @jax.jit
    def run(seed_key):
        state = feddec.init_state(jnp.zeros(D, xs.dtype), fcfg.n_agents)

        def body(carry, _):
            state, key = carry
            key, kb, ks = jax.random.split(key, 3)
            idx = jax.random.randint(kb, (h, N, M_BATCH), 0, M_ROWS)
            xb = jnp.take_along_axis(xs[None], idx[..., None], axis=2)
            yb = jnp.take_along_axis(ys[None], idx, axis=2)
            state, metrics = round_fn(state, (xb, yb), ks)
            return (state, key), metrics["subopt"]

        (final_state, _), sub = jax.lax.scan(body, (state, seed_key),
                                             jnp.arange(n_rounds))
        sub = sub.reshape(-1)  # (n_rounds, H) -> (t_steps,)
        return sub[::record_every], subopt(final_state.params)

    return run


def run_experiment(t_steps: int = T, seeds: int = SEEDS,
                   record_every: int = 50):
    jax.config.update("jax_enable_x64", True)
    problem = linreg.make_problem(n=N, m_rows=M_ROWS, d=D, seed=0)
    graphs = {"sparse_r0.35": topo.geographic_graph(N, 0.35, seed=1),
              "dense_r0.50": topo.geographic_graph(N, 0.50, seed=1)}
    rows, finals = [], {}
    for gname, graph in graphs.items():
        for h in (10, 100):
            for alg in ("feddec", "fedavg"):
                if alg == "feddec":
                    fcfg = feddec.FedDecConfig(
                        mixing=MixingDistribution(graph, scheme="laplacian"),
                        h=h, k=K)
                else:
                    fcfg = FedAvgConfig(N, h=h, k=K)
                runner = _make_runner(problem, fcfg, t_steps, record_every)
                keys = jax.random.split(jax.random.key(42), seeds)
                curves, last = jax.vmap(runner)(keys)
                mean_curve = np.asarray(curves.mean(axis=0))
                finals[(gname, h, alg)] = float(np.asarray(last).mean())
                for i, v in enumerate(mean_curve):
                    rows.append((gname, h, alg, i * record_every, float(v)))
    return rows, finals


def validate(finals: dict) -> list[str]:
    checks = []
    for g in ("sparse_r0.35", "dense_r0.50"):
        for h in (10, 100):
            dec, avg = finals[(g, h, "feddec")], finals[(g, h, "fedavg")]
            checks.append(
                f"C1 {g} H={h}: feddec {dec:.3e} < fedavg {avg:.3e}: "
                f"{'PASS' if dec < avg else 'FAIL'}")
    for g in ("sparse_r0.35", "dense_r0.50"):
        gain10 = finals[(g, 10, "fedavg")] / finals[(g, 10, "feddec")]
        gain100 = finals[(g, 100, "fedavg")] / finals[(g, 100, "feddec")]
        checks.append(f"C2 {g}: gain(H=100)={gain100:.2f} > "
                      f"gain(H=10)={gain10:.2f}: "
                      f"{'PASS' if gain100 > gain10 else 'FAIL'}")
    for h in (10, 100):
        gs = finals[("sparse_r0.35", h, "fedavg")] / \
            finals[("sparse_r0.35", h, "feddec")]
        gd = finals[("dense_r0.50", h, "fedavg")] / \
            finals[("dense_r0.50", h, "feddec")]
        checks.append(f"C3 H={h}: dense gain {gd:.2f} > sparse gain "
                      f"{gs:.2f}: {'PASS' if gd > gs else 'FAIL'}")
    return checks


def main(t_steps: int = T, seeds: int = SEEDS) -> None:
    import time
    t0 = time.perf_counter()
    rows, finals = run_experiment(t_steps, seeds)
    common.write_csv("fig4_convergence.csv",
                     ["graph", "H", "alg", "t", "suboptimality"], rows)
    checks = validate(finals)
    for c in checks:
        print("#", c)
    n_pass = sum("PASS" in c for c in checks)
    common.emit("fig4_feddec_vs_fedavg",
                (time.perf_counter() - t0) * 1e6,
                f"claims_pass={n_pass}/{len(checks)}")


if __name__ == "__main__":
    main()
