"""Batched sweep engine vs per-seed Python loop — the lattice cost model.

The paper's figures are sweeps over (seed × H × topology); before the sweep
engine every figure script drove the flat engine once per run, paying a
full dispatch + host-sync round-trip per run per window while the device
idled between microscopic (n=20, D=25) kernels.  The sweep engine
(repro.core.sweep) stacks the whole lattice into one ``(R, n, D)`` buffer
and scans all runs in one compiled program.

This benchmark times, at the Fig. 4 workload shape (linreg n=20, d=25,
H=10, K=2, geographic graph, Laplacian weights, Theorem-1 stepsize):

  * ``loop``  — the per-seed baseline: one jitted single-run flat-engine
    H-step round per run per server window (compiled once, dispatched
    R·(T/H) times per trajectory with the state round-tripping through the
    host between windows) — exactly the pre-sweep figure-driver /
    train-loop pattern;
  * ``sweep`` — one batched call covering all R runs × T steps.

Both execute the identical T-step trajectories (each sweep slice is checked
against its single-run flat engine at 1e-5; observed exact), so
``loop_us / sweep_us`` is a pure throughput ratio at equal work.  Every row
carries the sweep cost model's exact columns
(``launch.analysis.sweep_cost_model``: state bytes, per-step streamed
bytes, dispatch counts) — pinned by CI's regression guard.

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_sweep.json (smoke runs write
BENCH_sweep.smoke.json so the committed baseline is never clobbered).

Run:  PYTHONPATH=src python -m benchmarks.bench_sweep [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feddec, flat as flat_lib, sweep, topology as topo
from repro.core.mixing import MixingDistribution
from repro.data import linreg
from repro.launch import analysis

N, D, M_ROWS, K = 20, 25, 10, 2  # fig4 shapes
FIG4_H = 10


def _setup(problem):
    graph = topo.geographic_graph(problem.n, 0.5, seed=1)
    fcfg = feddec.FedDecConfig(
        mixing=MixingDistribution(graph, scheme="laplacian"), h=FIG4_H, k=K)
    lr = common.paper_lr_fn(problem, FIG4_H)
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    spec = flat_lib.make_flat_spec(jnp.zeros(problem.d))
    return fcfg, lr, grad_fn, spec


def bench_one(r_runs: int, t_steps: int, *, warmup: int, iters: int,
              check: bool) -> dict:
    problem = linreg.make_problem(n=N, m_rows=M_ROWS, d=D, seed=0)
    fcfg, lr, grad_fn, spec = _setup(problem)
    plan = sweep.make_sweep_plan([fcfg] * r_runs)

    # shared batch stream per step (the throughput comparison is about
    # execution, not data generation), per-run keys as in the figure scripts
    batches = jax.vmap(lambda k: linreg.sample_minibatch(problem, k, m=1))(
        jax.random.split(jax.random.key(3), t_steps))
    run_keys = jax.random.split(jax.random.key(42), r_runs)
    bat_sweep = jax.tree.map(
        lambda b: jnp.broadcast_to(b[:, None],
                                   (t_steps, r_runs) + b.shape[1:]), batches)

    # per-seed loop baseline: one compiled single-run H-step round,
    # dispatched per run per server window (batches pre-sliced outside the
    # timed region so the loop pays only dispatch + sync, as in bench_fused)
    assert t_steps % FIG4_H == 0, (t_steps, FIG4_H)
    win_batches = [
        jax.block_until_ready(jax.tree.map(
            lambda b: b[w * FIG4_H:(w + 1) * FIG4_H], batches))
        for w in range(t_steps // FIG4_H)]
    flat_round = flat_lib.make_flat_feddec_round(fcfg, spec, grad_fn, lr,
                                                 donate=False)
    state1 = flat_lib.init_flat_state(spec, jnp.zeros(D), N)

    def run_loop():
        outs = []
        for r in range(r_runs):
            st = state1
            for wb in win_batches:
                st, _ = flat_round(st, wb, run_keys[r])
            outs.append(st.flat)
        return outs

    sweep_round = sweep.make_sweep_feddec_round(plan, spec, grad_fn, lr,
                                                donate=False)
    state_r = sweep.init_sweep_state(plan, spec, jnp.zeros(D))

    def run_sweep():
        st, _ = sweep_round(state_r, bat_sweep, run_keys)
        return st.flat

    max_err = None
    if check:  # every sweep slice == its single-run flat trajectory
        ref = np.stack([np.asarray(o) for o in run_loop()])
        got = np.asarray(run_sweep())
        max_err = float(np.abs(got - ref).max())
        np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)

    us_loop = common.time_fn(run_loop, warmup=warmup, iters=iters)
    us_sweep = common.time_fn(run_sweep, warmup=warmup, iters=iters)
    model = analysis.sweep_cost_model(
        r_runs=r_runs, n_agents=N, d=spec.d, t_steps=t_steps, h=FIG4_H,
        param_bytes=4)
    speedup = us_loop / us_sweep
    steps_per_s = r_runs * t_steps / (us_sweep / 1e6)
    row = {"r_runs": r_runs, "n_agents": N, "d": spec.d,
           "t_steps": t_steps, "h": FIG4_H,
           "us_per_call": round(us_sweep, 1),
           "loop_us_per_call": round(us_loop, 1),
           "speedup": round(speedup, 2),
           "run_steps_per_s": round(steps_per_s, 1),
           "max_slice_err": max_err,
           "state_bytes": model["state_bytes"],
           "step_stream_bytes": model["step_stream_bytes"],
           "dispatches_loop": model["dispatches_loop"],
           "dispatches_sweep": model["dispatches_sweep"]}
    common.emit(f"sweep_R{r_runs}_T{t_steps}", us_sweep,
                f"loop_us={us_loop:.1f};speedup={speedup:.2f}x")
    return row


def main(smoke: bool = False) -> None:
    if smoke:
        warmup, iters, t_steps = 1, 3, 30
        grid = (4, 10)
    else:
        warmup, iters, t_steps = 2, 8, 200
        grid = (4, 10, 20, 40)

    rows = [bench_one(r, t_steps, warmup=warmup, iters=iters, check=True)
            for r in grid]

    fig4_row = next(r for r in rows if r["r_runs"] == 10)  # fig4's seed count
    acceptance = {
        "fig4_shape": {"n_agents": N, "d": D, "h": FIG4_H, "k": K,
                       "t_steps": t_steps, "seeds": 10},
        "speedup_at_fig4_seeds": fig4_row["speedup"],
        "best_speedup": max(r["speedup"] for r in rows),
        "equivalence_checked_vs_flat": True,
        "max_slice_err": max(r["max_slice_err"] for r in rows),
        "note": ("loop = one jitted single-run flat H-step round "
                 "dispatched per run per server window (R·T/H dispatches "
                 "— the pre-sweep figure-driver / train-loop pattern); "
                 "sweep = one batched (R, n, D) program for the whole "
                 "lattice.  Identical trajectories (slices checked at "
                 "1e-5), so the ratio is pure throughput.  CPU CI "
                 "numbers; the dispatch-count and state/stream-byte "
                 "columns are the transferable evidence "
                 "(launch.analysis.sweep_cost_model)."),
    }
    out = {"workload": "FedDec linreg sweep lattice at fig4 shapes",
           "backend": jax.default_backend(), "smoke": smoke,
           "rows": rows, "acceptance": acceptance}
    name = "BENCH_sweep.smoke.json" if smoke else "BENCH_sweep.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv("bench_sweep.csv", list(rows[0].keys()),
                     [tuple(r.values()) for r in rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / few iterations for CI")
    args = p.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
