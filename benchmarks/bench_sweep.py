"""Batched sweep engine vs per-seed Python loop — the lattice cost model.

The paper's figures are sweeps over (seed × H × topology); before the sweep
engine every figure script drove the flat engine once per run, paying a
full dispatch + host-sync round-trip per run per window while the device
idled between microscopic (n=20, D=25) kernels.  The sweep engine
(repro.core.sweep) stacks the whole lattice into one ``(R, n, D)`` buffer
and scans all runs in one compiled program.

This benchmark times, at the Fig. 4 workload shape (linreg n=20, d=25,
H=10, K=2, geographic graph, Laplacian weights, Theorem-1 stepsize):

  * ``loop``  — the per-seed baseline: one jitted single-run flat-engine
    H-step round per run per server window (compiled once, dispatched
    R·(T/H) times per trajectory with the state round-tripping through the
    host between windows) — exactly the pre-sweep figure-driver /
    train-loop pattern;
  * ``sweep`` — one batched call covering all R runs × T steps.

Both execute the identical T-step trajectories (each sweep slice is checked
against its single-run flat engine at 1e-5; observed exact), so
``loop_us / sweep_us`` is a pure throughput ratio at equal work.  Every row
carries the sweep cost model's exact columns
(``launch.analysis.sweep_cost_model``: state bytes, per-step streamed
bytes, dispatch counts) — pinned by CI's regression guard.

A second section measures the composed lowering — ``sweep_runs`` R ×
``mesh_agents`` s in ONE shard_map program
(repro.core.engine.make_sharded_sweep_round) — as weak scaling at 4
agents per shard (n = 4·s for s ∈ {1, 2, 4, 8}, R = 4) under 8 forced
host devices.  It runs in a child process (same isolation pattern as
bench_sharded) so the parent's jax device state is never touched; every
row's byte/dispatch columns are exact against
``launch.analysis.sharded_sweep_cost_model`` and every run slice is
checked against its single-run flat trajectory at 1e-5.

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_sweep.json (smoke runs write
BENCH_sweep.smoke.json so the committed baseline is never clobbered).

Run:  PYTHONPATH=src python -m benchmarks.bench_sweep [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import feddec, flat as flat_lib, sweep, topology as topo
from repro.core.mixing import MixingDistribution
from repro.data import linreg
from repro.launch import analysis

N, D, M_ROWS, K = 20, 25, 10, 2  # fig4 shapes
FIG4_H = 10
N_DEVICES = 8
SHARDED_R = 4            # runs in the composed lattice
AGENTS_PER_SHARD = 4     # weak scaling: n = AGENTS_PER_SHARD * n_shards
_PART = "BENCH_sweep.sharded.part.json"  # child → parent handoff


def _setup(problem):
    graph = topo.geographic_graph(problem.n, 0.5, seed=1)
    fcfg = feddec.FedDecConfig(
        mixing=MixingDistribution(graph, scheme="laplacian"), h=FIG4_H, k=K)
    lr = common.paper_lr_fn(problem, FIG4_H)
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    spec = flat_lib.make_flat_spec(jnp.zeros(problem.d))
    return fcfg, lr, grad_fn, spec


def bench_one(r_runs: int, t_steps: int, *, warmup: int, iters: int,
              check: bool) -> dict:
    problem = linreg.make_problem(n=N, m_rows=M_ROWS, d=D, seed=0)
    fcfg, lr, grad_fn, spec = _setup(problem)
    plan = sweep.make_sweep_plan([fcfg] * r_runs)

    # shared batch stream per step (the throughput comparison is about
    # execution, not data generation), per-run keys as in the figure scripts
    batches = jax.vmap(lambda k: linreg.sample_minibatch(problem, k, m=1))(
        jax.random.split(jax.random.key(3), t_steps))
    run_keys = jax.random.split(jax.random.key(42), r_runs)
    bat_sweep = jax.tree.map(
        lambda b: jnp.broadcast_to(b[:, None],
                                   (t_steps, r_runs) + b.shape[1:]), batches)

    # per-seed loop baseline: one compiled single-run H-step round,
    # dispatched per run per server window (batches pre-sliced outside the
    # timed region so the loop pays only dispatch + sync, as in bench_fused)
    assert t_steps % FIG4_H == 0, (t_steps, FIG4_H)
    win_batches = [
        jax.block_until_ready(jax.tree.map(
            lambda b: b[w * FIG4_H:(w + 1) * FIG4_H], batches))
        for w in range(t_steps // FIG4_H)]
    flat_round = flat_lib.make_flat_feddec_round(fcfg, spec, grad_fn, lr,
                                                 donate=False)
    state1 = flat_lib.init_flat_state(spec, jnp.zeros(D), N)

    def run_loop():
        outs = []
        for r in range(r_runs):
            st = state1
            for wb in win_batches:
                st, _ = flat_round(st, wb, run_keys[r])
            outs.append(st.flat)
        return outs

    sweep_round = sweep.make_sweep_feddec_round(plan, spec, grad_fn, lr,
                                                donate=False)
    state_r = sweep.init_sweep_state(plan, spec, jnp.zeros(D))

    def run_sweep():
        st, _ = sweep_round(state_r, bat_sweep, run_keys)
        return st.flat

    max_err = None
    if check:  # every sweep slice == its single-run flat trajectory
        ref = np.stack([np.asarray(o) for o in run_loop()])
        got = np.asarray(run_sweep())
        max_err = float(np.abs(got - ref).max())
        np.testing.assert_allclose(got, ref, atol=1e-5, rtol=1e-5)

    us_loop = common.time_fn(run_loop, warmup=warmup, iters=iters)
    us_sweep = common.time_fn(run_sweep, warmup=warmup, iters=iters)
    model = analysis.sweep_cost_model(
        r_runs=r_runs, n_agents=N, d=spec.d, t_steps=t_steps, h=FIG4_H,
        param_bytes=4)
    speedup = us_loop / us_sweep
    steps_per_s = r_runs * t_steps / (us_sweep / 1e6)
    row = {"r_runs": r_runs, "n_agents": N, "d": spec.d,
           "t_steps": t_steps, "h": FIG4_H,
           "us_per_call": round(us_sweep, 1),
           "loop_us_per_call": round(us_loop, 1),
           "speedup": round(speedup, 2),
           "run_steps_per_s": round(steps_per_s, 1),
           "max_slice_err": max_err,
           "state_bytes": model["state_bytes"],
           "step_stream_bytes": model["step_stream_bytes"],
           "dispatches_loop": model["dispatches_loop"],
           "dispatches_sweep": model["dispatches_sweep"]}
    common.emit(f"sweep_R{r_runs}_T{t_steps}", us_sweep,
                f"loop_us={us_loop:.1f};speedup={speedup:.2f}x")
    return row


def _bench_sharded_sweep_child(smoke: bool) -> None:
    """Weak-scaling rows of the composed R runs × s shards lowering.

    Runs inside the forced-8-device child; writes the rows to the part
    file the parent merges into BENCH_sweep.json.
    """
    from repro.core import engine
    from repro.launch.mesh import make_agent_mesh

    assert len(jax.devices()) >= N_DEVICES, "forced host devices missing"
    if smoke:
        warmup, iters, t_steps = 1, 3, 30
        shard_grid = (1, 8)
    else:
        warmup, iters, t_steps = 2, 5, 200
        shard_grid = (1, 2, 4, 8)

    rows = []
    for n_shards in shard_grid:
        n = AGENTS_PER_SHARD * n_shards
        # c_base=1 keeps the label scale O(1) as n grows (the paper's
        # c_i = 2^i ramp reaches 2^32 at the widest row, which would make
        # the absolute 1e-5 slice check vacuous); constant stepsize under
        # the smoothness bound for the same reason — neither affects timing
        problem = linreg.make_problem(n=n, m_rows=M_ROWS, d=D, seed=0,
                                      c_base=1.0)
        graph = topo.ring_graph(n, k=1)
        fcfg = feddec.FedDecConfig(
            mixing=MixingDistribution(graph, scheme="laplacian"),
            h=FIG4_H, k=K)
        eta = jnp.asarray(0.5 / problem.l_smooth, jnp.float32)
        lr = lambda t: eta  # noqa: E731
        grad_fn = linreg.make_grad_fn(problem.m_rows)
        spec = flat_lib.make_flat_spec(jnp.zeros(problem.d))
        plan = sweep.make_sweep_plan([fcfg] * SHARDED_R)
        mesh = make_agent_mesh(n_shards)

        batches = jax.vmap(
            lambda k: linreg.sample_minibatch(problem, k, m=1))(
            jax.random.split(jax.random.key(3), t_steps))
        run_keys = jax.random.split(jax.random.key(42), SHARDED_R)
        bat_sweep = jax.tree.map(
            lambda b: jnp.broadcast_to(
                b[:, None], (t_steps, SHARDED_R) + b.shape[1:]), batches)

        round_fn = engine.make_sharded_sweep_round(plan, spec, grad_fn, lr,
                                                   mesh, donate=False)
        state0 = engine.shard_sweep_state(
            sweep.init_sweep_state(plan, spec, jnp.zeros(problem.d)), mesh)

        # every run slice == its single-run flat trajectory
        flat_round = flat_lib.make_flat_feddec_round(fcfg, spec, grad_fn,
                                                     lr, donate=False)
        out, _ = round_fn(state0, bat_sweep, run_keys)
        got = np.asarray(jax.device_get(out.flat))
        max_err = 0.0
        for r in range(SHARDED_R):
            s_ref, _ = flat_round(
                flat_lib.init_flat_state(spec, jnp.zeros(problem.d), n),
                batches, run_keys[r])
            err = float(np.abs(got[r] - np.asarray(s_ref.flat)).max())
            max_err = max(max_err, err)
            np.testing.assert_allclose(got[r], np.asarray(s_ref.flat),
                                       atol=1e-5, rtol=1e-5)

        us = common.time_fn(lambda: round_fn(state0, bat_sweep, run_keys),
                            warmup=warmup, iters=iters)
        from repro.core import sharded as sharded_lib
        cut = sharded_lib.cut_edge_stats(graph, n_shards)
        model = analysis.sharded_sweep_cost_model(
            r_runs=SHARDED_R, n_agents=n, d=spec.d, n_shards=n_shards,
            num_halo_rounds=cut["num_halo_rounds"], t_steps=t_steps,
            h=FIG4_H, param_bytes=4)
        run_steps_per_s = SHARDED_R * t_steps / (us / 1e6)
        rows.append({
            "r_runs": SHARDED_R, "n_agents": n, "n_shards": n_shards,
            "agents_per_shard": AGENTS_PER_SHARD, "d": spec.d,
            "t_steps": t_steps, "h": FIG4_H,
            "us_per_call": round(us, 1),
            "run_steps_per_s": round(run_steps_per_s, 1),
            "max_slice_err": max_err,
            "state_bytes_per_device": model["state_bytes_per_device"],
            "step_stream_bytes_per_device":
                model["step_stream_bytes_per_device"],
            "dense_collective_bytes": model["dense_collective_bytes"],
            "halo_collective_bytes": model["halo_collective_bytes"],
            "num_halo_rounds": model["num_halo_rounds"],
            "dispatches_loop": model["dispatches_loop"],
            "dispatches_sweep": model["dispatches_sweep"]})
        common.emit(f"sharded_sweep_R{SHARDED_R}_n{n}_s{n_shards}", us,
                    f"slice_err={max_err:.1e};"
                    f"halo_bytes={model['halo_collective_bytes']:.0f}")

    path = os.path.join(common.ensure_results_dir(), _PART)
    with open(path, "w") as f:
        json.dump({"sharded_rows": rows}, f)
    print(f"# wrote {path}")


def _run_sharded_sweep_section(smoke: bool) -> list[dict]:
    """Respawn into a forced-8-device child and collect its rows."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={N_DEVICES} "
                        + env.get("XLA_FLAGS", "")).strip()
    env.setdefault("PYTHONPATH", os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    cmd = [sys.executable, "-m", "benchmarks.bench_sweep", "--sharded-child"]
    if smoke:
        cmd.append("--smoke")
    res = subprocess.run(cmd, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError(f"bench_sweep sharded child failed "
                           f"({res.returncode})")
    path = os.path.join(common.ensure_results_dir(), _PART)
    with open(path) as f:
        rows = json.load(f)["sharded_rows"]
    os.remove(path)
    return rows


def main(smoke: bool = False) -> None:
    if smoke:
        warmup, iters, t_steps = 1, 3, 30
        grid = (4, 10)
    else:
        warmup, iters, t_steps = 2, 8, 200
        grid = (4, 10, 20, 40)

    rows = [bench_one(r, t_steps, warmup=warmup, iters=iters, check=True)
            for r in grid]
    sharded_rows = _run_sharded_sweep_section(smoke)

    fig4_row = next(r for r in rows if r["r_runs"] == 10)  # fig4's seed count
    acceptance = {
        "fig4_shape": {"n_agents": N, "d": D, "h": FIG4_H, "k": K,
                       "t_steps": t_steps, "seeds": 10},
        "speedup_at_fig4_seeds": fig4_row["speedup"],
        "best_speedup": max(r["speedup"] for r in rows),
        "equivalence_checked_vs_flat": True,
        "max_slice_err": max(r["max_slice_err"] for r in rows),
        "sharded_sweep": {
            "devices": N_DEVICES, "r_runs": SHARDED_R,
            "agents_per_shard": AGENTS_PER_SHARD,
            "max_slice_err": max(r["max_slice_err"] for r in sharded_rows),
            "equivalence_checked_vs_flat": True,
            "note": ("the composed lowering: R runs × s agent shards as "
                     "one shard_map program "
                     "(repro.core.engine.make_sharded_sweep_round).  Weak "
                     "scaling at 4 agents/shard: per-device state and "
                     "streamed bytes stay constant as agents are added "
                     "with devices "
                     "(analysis.sharded_sweep_cost_model columns)")},
        "note": ("loop = one jitted single-run flat H-step round "
                 "dispatched per run per server window (R·T/H dispatches "
                 "— the pre-sweep figure-driver / train-loop pattern); "
                 "sweep = one batched (R, n, D) program for the whole "
                 "lattice.  Identical trajectories (slices checked at "
                 "1e-5), so the ratio is pure throughput.  CPU CI "
                 "numbers; the dispatch-count and state/stream-byte "
                 "columns are the transferable evidence "
                 "(launch.analysis.sweep_cost_model)."),
    }
    out = {"workload": "FedDec linreg sweep lattice at fig4 shapes",
           "backend": jax.default_backend(), "smoke": smoke,
           "rows": rows, "sharded_rows": sharded_rows,
           "acceptance": acceptance}
    name = "BENCH_sweep.smoke.json" if smoke else "BENCH_sweep.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv("bench_sweep.csv", list(rows[0].keys()),
                     [tuple(r.values()) for r in rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / few iterations for CI")
    p.add_argument("--sharded-child", action="store_true",
                   help="internal: run the sharded-sweep section (assumes "
                        "the forced-device XLA flag is already set)")
    args = p.parse_args()
    if args.sharded_child:
        _bench_sharded_sweep_child(smoke=args.smoke)
    else:
        print("name,us_per_call,derived")
        main(smoke=args.smoke)
