"""Gossip execution paths head-to-head: tree leaf-wise vs flat whole-buffer.

The hot op of Algorithm 1 is the mix x_i ← Σ_j W_ij x_j, executed every
step.  The tree engine applies it leaf-wise over the parameter pytree (one
einsum / kernel call per leaf, per-leaf padding, per-leaf dispatch inside the
scan); the flat engine (repro.core.flat) applies it once to the contiguous
(n_agents, D) buffer.  This benchmark times, for a model-shaped ragged pytree
and its flat buffer, across n_agents × D:

  * ``tree_dense``   — leaf-wise einsum (repro.core.gossip.gossip_mix_dense);
  * ``tree_pallas``  — leaf-wise Pallas kernel (kernels.ops.gossip_mix_tree);
  * ``flat_dense``   — one whole-buffer einsum;
  * ``flat_pallas``  — one kernels.ops.gossip_mix call (the flat engine's
    ``gossip_impl='pallas'`` op; interpret mode off-TPU);
  * ``flat_sparse``  — CSR gather + segment_sum (``gossip_impl='sparse'``),
    plus the n=256 showcase the dense contraction cannot sustain.

Every row carries its measured wall-clock AND the dispatch/bytes cost model
(one mixing op per leaf vs per buffer; f32-upcast tax; 2|E|D vs 2n²D FLOPs)
— on this CPU container the Pallas kernel runs in interpret mode, so the
kernel rows' wall-clock is not TPU-representative and the dispatch/bytes
columns are the evidence that transfers (the whole-buffer einsum measures
the same single-streaming-pass shape the kernel executes on TPU).

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_gossip.json (consumed by CI's bench smoke job and
docs/PERFORMANCE.md).

Run:  PYTHONPATH=src python -m benchmarks.bench_gossip [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.core import flat as flat_lib
from repro.core import gossip as gossip_lib
from repro.core import topology as topo
from repro.core.mixing import MixingDistribution
from repro.kernels import ops as kernel_ops
from repro.launch import analysis


def make_model_tree(key, n: int, d_target: int, m: int = 128):
    """A transformer-shaped ragged stacked pytree totalling ≈ d_target.

    Per block: qkv (m, 3m), o (m, m), up (m, 4m), down (4m, m) plus three
    (m,) vectors — the big-matrices-plus-many-small-leaves profile real
    checkpoints have, which is exactly what the leaf-wise path pays for.
    """
    block = {"qkv": (m, 3 * m), "o": (m, m), "up": (m, 4 * m),
             "down": (4 * m, m), "ln1": (m,), "ln2": (m,), "bias": (m,)}
    block_size = sum(int(np.prod(s)) for s in block.values())
    layers = max(1, d_target // block_size)
    tree = {}
    total = 0
    for i in range(layers):
        layer = {}
        for name, shape in block.items():
            key, k = jax.random.split(key)
            layer[name] = jax.random.normal(k, (n,) + shape, jnp.float32)
            total += int(np.prod(shape))
        tree[f"layer{i}"] = layer
    rem = d_target - total
    if rem > 0:
        key, k = jax.random.split(key)
        tree["embed"] = jax.random.normal(k, (n, rem), jnp.float32)
    return tree


def _impls(graph, w, block_d: int):
    """name -> (jitted fn over the tree or the flat buffer, layout)."""
    sparse_mix = gossip_lib.make_sparse_gossip(graph)
    return {
        "tree_dense": (jax.jit(lambda x: gossip_lib.gossip_mix_dense(w, x)),
                       "tree"),
        "tree_pallas": (jax.jit(lambda x: kernel_ops.gossip_mix_tree(w, x)),
                        "tree"),
        "flat_dense": (jax.jit(lambda x: jnp.einsum(
            "ij,jd->id", w, x, precision=jax.lax.Precision.HIGHEST)),
            "flat"),
        "flat_pallas": (jax.jit(lambda x: kernel_ops.gossip_mix(
            w, x, block_d=block_d)), "flat"),
        "flat_sparse": (jax.jit(lambda x: sparse_mix(w, x)), "flat"),
    }


def bench_grid(n: int, d_target: int, *, warmup: int, iters: int,
               block_d: int, check: bool, m: int = 128) -> list[dict]:
    graph = topo.ring_graph(n, k=2)
    w = jnp.asarray(MixingDistribution(graph, scheme="metropolis")
                    .sample(jax.random.key(0)))
    tree = make_model_tree(jax.random.key(1), n, d_target, m=m)
    spec = flat_lib.make_flat_spec_from_stacked(tree)
    buf = spec.flatten(tree)
    d = spec.d
    n_leaves = spec.num_leaves
    model = analysis.gossip_cost_model(
        n_agents=n, d=d, num_leaves=n_leaves,
        num_directed_edges=2 * graph.num_edges, param_bytes=4)

    impls = _impls(graph, w, block_d)
    if check:  # all paths compute the same mix (1e-4; bf16-free f32 here)
        ref = np.asarray(impls["flat_dense"][0](buf))
        for name, (fn, layout) in impls.items():
            got = fn(tree if layout == "tree" else buf)
            got = np.asarray(spec.flatten(got) if layout == "tree" else got)
            np.testing.assert_allclose(got, ref, atol=1e-4, rtol=1e-4)

    rows = []
    for name, (fn, layout) in impls.items():
        arg = tree if layout == "tree" else buf
        us = common.time_fn(fn, arg, warmup=warmup, iters=iters)
        cm = model.get(name, model["flat_dense"])
        if name == "tree_pallas":
            cm = {**model["flat_pallas"], "dispatches": n_leaves}
        row = {"impl": name, "n_agents": n, "d": d, "num_leaves": n_leaves,
               "us_per_call": round(us, 1),
               "dispatches_per_gossip": cm["dispatches"],
               "model_bytes": cm["bytes"], "model_flops": cm["flops"],
               "interpret_mode": "pallas" in name and not kernel_ops.on_tpu()}
        rows.append(row)
        common.emit(f"gossip_{name}_n{n}_d{d}", us,
                    f"dispatches={cm['dispatches']};layout={layout}")
    return rows


def bench_large_n_sparse(n: int, d_target: int, *, warmup: int,
                         iters: int) -> dict:
    """The n=256 regime: sparse ring completes; dense is n²/|E| ≈ 64× the
    FLOPs (measured once for the ratio — this is the 'cannot sustain' row)."""
    graph = topo.ring_graph(n, k=1)
    w = jnp.asarray(MixingDistribution(graph, scheme="metropolis")
                    .sample(jax.random.key(0)))
    x = jax.random.normal(jax.random.key(2), (n, d_target), jnp.float32)
    sparse_fn = jax.jit(gossip_lib.make_sparse_gossip(graph))
    dense_fn = jax.jit(lambda xx: jnp.einsum(
        "ij,jd->id", w, xx, precision=jax.lax.Precision.HIGHEST))
    us_sparse = common.time_fn(lambda: sparse_fn(w, x),
                               warmup=warmup, iters=iters)
    us_dense = common.time_fn(lambda: dense_fn(x), warmup=1, iters=1)
    np.testing.assert_allclose(np.asarray(sparse_fn(w, x)),
                               np.asarray(dense_fn(x)), atol=1e-4, rtol=1e-4)
    common.emit(f"gossip_sparse_ring_n{n}_d{d_target}", us_sparse,
                f"dense_us={us_dense:.1f};ratio={us_dense / us_sparse:.1f}x")
    return {"n_agents": n, "d": d_target,
            "num_directed_edges": 2 * graph.num_edges,
            "sparse_us": round(us_sparse, 1), "dense_us": round(us_dense, 1),
            "dense_over_sparse": round(us_dense / us_sparse, 2),
            "flop_ratio_dense_over_sparse":
                round(n * n / (2.0 * graph.num_edges), 1)}


def main(smoke: bool = False) -> None:
    if smoke:
        warmup, iters, m = 1, 3, 32
        grid = [(8, 1 << 14)]
        block_d = 1 << 14
        large = [(64, 1 << 12)]
    else:
        warmup, iters, m = 1, 5, 128
        grid = [(8, 1 << 20), (32, 1 << 20)]
        block_d = 1 << 20  # one grid step: the whole-buffer streaming pass
        large = [(256, 1 << 17), (1024, 1 << 14)]

    rows = []
    for n, d_target in grid:
        rows.extend(bench_grid(n, d_target, warmup=warmup, iters=iters,
                               block_d=block_d, check=True, m=m))
    large_rows = [bench_large_n_sparse(n, d, warmup=warmup, iters=iters)
                  for n, d in large]

    def us_of(impl, n):
        return next(r["us_per_call"] for r in rows
                    if r["impl"] == impl and r["n_agents"] == n)

    n_big = grid[-1][0]
    acceptance = {
        "at_n": n_big, "at_d": next(r["d"] for r in rows
                                    if r["n_agents"] == n_big),
        # like-for-like kernel evidence: the same Pallas gossip kernel
        # applied leaf-wise (per-leaf padding + per-leaf grid dispatch —
        # the pre-flat engine) vs once over the whole buffer
        "speedup_flat_pallas_vs_leafwise_pallas":
            round(us_of("tree_pallas", n_big) / us_of("flat_pallas", n_big),
                  2),
        "speedup_flat_dense_vs_tree_dense":
            round(us_of("tree_dense", n_big) / us_of("flat_dense", n_big), 2),
        "speedup_flat_pallas_vs_tree_dense":
            round(us_of("tree_dense", n_big) / us_of("flat_pallas", n_big),
                  2),
        "dispatch_reduction": next(r["num_leaves"] for r in rows
                                   if r["n_agents"] == n_big),
        "pallas_interpret_mode": not kernel_ops.on_tpu(),
        "sparse_large_n": large_rows,
        "note": ("off-TPU the Pallas rows run in interpret mode and this "
                 "container is memory-bandwidth-starved (~2 GB/s), so "
                 "XLA-einsum wall-clock ratios between layouts are "
                 "threading noise; the transferable evidence is (a) the "
                 "leaf-wise vs whole-buffer ratio of the SAME kernel, "
                 "(b) dispatches_per_gossip, and (c) the model_bytes/"
                 "model_flops columns evaluated at TPU constants "
                 "(launch.analysis.gossip_cost_model)"),
    }
    out = {"workload": "gossip mix y = W @ x on model-shaped stacked params",
           "backend": jax.default_backend(), "smoke": smoke,
           "rows": rows, "acceptance": acceptance}
    # smoke runs get their own file so a local/CI --smoke never clobbers
    # the committed full-run baseline the regression guard diffs against
    name = "BENCH_gossip.smoke.json" if smoke else "BENCH_gossip.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv("bench_gossip.csv",
                     list(rows[0].keys()),
                     [tuple(r.values()) for r in rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / few iterations for CI")
    args = p.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
