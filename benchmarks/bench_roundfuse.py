"""Fused-round benchmark: one-pass update+gossip vs the two-pass body.

Four sections, one JSON:

  1. **engine** — the real flat executor at the fig4 linreg shape, fused
     (``fuse_update_mix=True`` → kernels/update_mix.py) vs unfused, across
     gossip impls × sgd/momentum × codec on/off.  Every fused trajectory is
     asserted against its unfused twin (final buffer within 1e-5) before it
     is timed, so the wall-clock columns always describe equivalent math.
  2. **headline** — the buffer-pass evidence at n=1024, D=2^20 (the 4 GiB
     flat buffer): the unfused body dispatches update and mix separately,
     materialising the post-update buffer p between them; the fused body
     is the same math in one dispatch, so p never round-trips through HBM.
     Off-TPU the Pallas kernels interpret (far too slow at 2^30 elements),
     so both sides run the identical XLA sparse-ELL composition and only
     the dispatch split differs — exactly the pass delta
     ``analysis.roundfuse_cost_model`` counts (sgd 5→3 passes, momentum
     7→5), which is what the regression guard pins, exact.
  3. **sharded** — the boundary/interior overlapped halo (8 forced host
     devices): ``sharded.boundary_row_split`` row counts, the cost model's
     halo_payload_ratio / predicted_overlap_fraction, measured round
     wall-clock, and a final-buffer check against the unsharded flat round.
  4. **block_d** — the measured sweep behind ``kernels.ops``'s
     ``autotune_block_d``: per-tile-width wall-clock at an
     interpret-feasible shape plus the table's choice at headline widths.

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_roundfuse.json (consumed by CI's perf-regression
guard and docs/PERFORMANCE.md).  Smoke runs write
BENCH_roundfuse.smoke.json so the committed baseline is never clobbered.

Run:  PYTHONPATH=src python -m benchmarks.bench_roundfuse [--smoke]

Re-executes itself in a forced-8-device subprocess (same isolation pattern
as bench_sharded.py).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

N_DEVICES = 8
HEADLINE_N = 1024
HEADLINE_D = 1 << 20


def main(smoke: bool = False) -> None:
    """Respawn into a forced-8-device subprocess and stream its output."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={N_DEVICES} "
                        + env.get("XLA_FLAGS", "")).strip()
    env.setdefault("PYTHONPATH", os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    cmd = [sys.executable, "-m", "benchmarks.bench_roundfuse", "--child"]
    if smoke:
        cmd.append("--smoke")
    res = subprocess.run(cmd, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError(f"bench_roundfuse child failed ({res.returncode})")


def _child_main(smoke: bool) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks import common
    from repro.core import flat as flat_lib
    from repro.core import sharded, theory, topology as topo
    from repro.core.feddec import FedDecConfig
    from repro.core.mixing import MixingDistribution
    from repro.data import linreg
    from repro.kernels import ops as kernel_ops
    from repro.launch import analysis
    from repro.launch.mesh import make_agent_mesh
    from repro.optim import optimizers as optim

    assert len(jax.devices()) >= N_DEVICES, "forced host devices missing"

    # t_engine stays at 40 in both modes: the fused-vs-unfused 1e-5 window
    # — like every trajectory-equivalence gate in this repo — is a short
    # horizon; past ~100 linreg steps the (equivalent) fusion-level float
    # noise is chaotically amplified and the comparison stops meaning
    # anything.  Full runs scale the *shapes*, not the horizon.
    t_engine = 40
    if smoke:
        warmup, iters = 1, 3
        head_n, head_d = 128, 1 << 14
        shard_d, shard_h = 1 << 10, 4
        block_d_sweep_d = 1 << 12
    else:
        warmup, iters = 1, 3  # the headline rows stream 4 GiB buffers
        head_n, head_d = HEADLINE_N, HEADLINE_D
        shard_d, shard_h = 1 << 12, 8
        block_d_sweep_d = 1 << 13

    def cost_cols(n, d, optimizer, codec):
        cm = analysis.roundfuse_cost_model(
            n_agents=n, d=d, optimizer=optimizer, codec=codec, param_bytes=4)
        return {k: cm[k] for k in ("passes_unfused", "passes_fused",
                                   "unfused_pass_bytes", "fused_pass_bytes",
                                   "pass_ratio")}

    # -- 1. engine: real fused executor at the fig4 shape ------------------
    problem = linreg.make_problem(n=8, seed=0, c_base=1.3)
    g_small = topo.geographic_graph(problem.n, 0.6, seed=3)
    md_small = MixingDistribution(g_small, scheme="laplacian")
    h = 10
    lr = theory.paper_stepsize(
        problem.mu, theory.gamma(problem.l_smooth, problem.mu, h))
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    spec = flat_lib.make_flat_spec(jnp.zeros(problem.d))
    keys_b = jax.random.split(jax.random.key(11), t_engine)
    batches = jax.vmap(lambda k: linreg.sample_minibatch(problem, k, m=1))(
        keys_b)

    engine_grid = [("dense", "sgd", "none"), ("dense", "momentum", "none"),
                   ("sparse", "sgd", "none"), ("sparse", "momentum", "none"),
                   ("pallas", "sgd", "none"), ("dense", "sgd", "int8"),
                   ("sparse", "sgd", "int8")]
    rows = []
    max_err_engine = 0.0
    for impl, opt_name, codec in engine_grid:
        cfg = FedDecConfig(mixing=md_small, h=h, k=2, gossip_impl=impl,
                           gossip_compress=codec)
        opt = optim.sgd() if opt_name == "sgd" else optim.momentum_sgd(0.9)
        finals = {}
        timed = {}
        for fused in (False, True):
            round_fn = flat_lib.make_flat_feddec_round(
                cfg, spec, grad_fn, lr, optimizer=opt, donate=False,
                fuse_update_mix=fused)
            state = flat_lib.init_flat_state(
                spec, jnp.zeros(problem.d), problem.n, optimizer=opt,
                compress=codec)
            out, _ = round_fn(state, batches, jax.random.key(5))
            finals[fused] = np.asarray(out.flat)
            timed[fused] = common.time_fn(
                round_fn, state, batches, jax.random.key(5),
                warmup=warmup, iters=iters)
        err = float(np.abs(finals[True] - finals[False]).max())
        np.testing.assert_allclose(finals[True], finals[False], atol=1e-5)
        max_err_engine = max(max_err_engine, err)
        row = {"section": "engine", "impl": impl, "optimizer": opt_name,
               "codec": codec != "none", "n_agents": problem.n,
               "d": problem.d, "t_steps": t_engine,
               "us_fused": round(timed[True], 1),
               "us_unfused": round(timed[False], 1),
               "speedup": round(timed[False] / timed[True], 3),
               "max_abs_err": err,
               **cost_cols(problem.n, problem.d, opt_name, codec != "none")}
        rows.append(row)
        common.emit(f"roundfuse_engine_{impl}_{opt_name}_{codec}",
                    timed[True],
                    f"speedup={row['speedup']};ratio={row['pass_ratio']:.3f}")

    # -- 2. headline: buffer-pass split at n=1024, D=2^20 ------------------
    graph = topo.ring_graph(head_n, k=2)
    md = MixingDistribution(graph, scheme="metropolis")
    w = jnp.asarray(md.sample(jax.random.key(0)))
    adj = np.asarray(graph.adjacency)
    max_deg = int(adj.sum(axis=1).max()) + 1  # neighbours + self
    nbr = np.zeros((head_n, max_deg), np.int32)
    for i in range(head_n):
        cols = [i] + list(np.flatnonzero(adj[i]))
        nbr[i, :len(cols)] = cols
        nbr[i, len(cols):] = i  # duplicates get zero weight below
    nbr_j = jnp.asarray(nbr)

    def ell_weights(w):
        wg = jnp.take_along_axis(w, nbr_j, axis=1)              # (n, deg)
        first = jnp.argmax(nbr_j[:, :, None] == nbr_j[:, None, :], axis=1)
        return jnp.where(first == jnp.arange(max_deg)[None], wg, 0.0)

    def ell_mix(w, p):
        wg = ell_weights(w)
        y = jnp.zeros_like(p)
        for j in range(max_deg):  # one (n, D) stream per neighbour slot
            y = y + wg[:, j, None] * jnp.take(p, nbr_j[:, j], axis=0)
        return y

    def update(x, g, eta, m=None):
        if m is None:
            return x - eta * g
        new_m = 0.9 * m + g
        return x - eta * new_m, new_m

    x = jax.random.normal(jax.random.key(1), (head_n, head_d), jnp.float32)
    g = jax.random.normal(jax.random.key(2), (head_n, head_d), jnp.float32)
    m0 = jnp.zeros_like(x)
    eta = jnp.float32(0.05)
    upd_sgd = jax.jit(update)
    upd_mom = jax.jit(update)
    mix = jax.jit(ell_mix)
    fused_sgd = jax.jit(lambda w, x, g, eta: ell_mix(w, update(x, g, eta)))

    def fused_mom_body(w, x, g, eta, m):
        p, new_m = update(x, g, eta, m)
        return ell_mix(w, p), new_m

    fused_mom = jax.jit(fused_mom_body)

    for opt_name in ("sgd", "momentum"):
        if opt_name == "sgd":
            def unfused_call():
                return mix(w, upd_sgd(x, g, eta))

            def fused_call():
                return fused_sgd(w, x, g, eta)
        else:
            def unfused_call():
                p, new_m = upd_mom(x, g, eta, m0)
                return mix(w, p), new_m

            def fused_call():
                return fused_mom(w, x, g, eta, m0)
        np.testing.assert_allclose(
            np.asarray(jax.tree.leaves(fused_call())[0]),
            np.asarray(jax.tree.leaves(unfused_call())[0]), atol=1e-5)
        us_un = common.time_fn(unfused_call, warmup=warmup, iters=iters)
        us_f = common.time_fn(fused_call, warmup=warmup, iters=iters)
        row = {"section": "headline", "impl": "sparse",
               "optimizer": opt_name, "codec": False, "n_agents": head_n,
               "d": head_d, "t_steps": 1,
               "us_fused": round(us_f, 1), "us_unfused": round(us_un, 1),
               "speedup": round(us_un / us_f, 3), "max_abs_err": 0.0,
               **cost_cols(head_n, head_d, opt_name, False)}
        rows.append(row)
        common.emit(f"roundfuse_headline_{opt_name}_n{head_n}_d{head_d}",
                    us_f,
                    f"speedup={row['speedup']};ratio={row['pass_ratio']:.3f}")
    del x, g, m0

    # -- 3. sharded: boundary/interior overlapped halo ---------------------
    n_sh, d_sh = 64, shard_d
    graph_sh = topo.ring_graph(n_sh, k=2)
    md_sh = MixingDistribution(graph_sh, scheme="metropolis")
    spec_sh = flat_lib.make_flat_spec(jnp.zeros(d_sh))

    def quad_grad(p, batch, key):
        del key
        return 0.5 * jnp.sum((p - batch) ** 2), p - batch

    def const_lr(t):
        return jnp.asarray(0.05, jnp.float32)

    batches_sh = jax.random.normal(jax.random.key(3), (shard_h, n_sh, d_sh),
                                   jnp.float32)
    key_sh = jax.random.key(4)
    cfg_sh = FedDecConfig(mixing=md_sh, h=shard_h, k=2, gossip_impl="sparse")
    flat_round = flat_lib.make_flat_feddec_round(
        cfg_sh, spec_sh, quad_grad, const_lr, donate=False)
    ref_state, _ = flat_round(
        flat_lib.init_flat_state(spec_sh, jnp.zeros(d_sh), n_sh),
        batches_sh, key_sh)
    ref_flat = np.asarray(ref_state.flat)

    sharded_rows = []
    for n_shards in (2, N_DEVICES):
        mesh = make_agent_mesh(n_shards)
        round_fn = sharded.make_sharded_feddec_round(
            cfg_sh, spec_sh, quad_grad, const_lr, mesh, donate=False)
        state = sharded.shard_flat_state(
            flat_lib.init_flat_state(spec_sh, jnp.zeros(d_sh), n_sh), mesh)
        out, _ = round_fn(state, batches_sh, key_sh)
        err = float(np.abs(np.asarray(out.flat) - ref_flat).max())
        np.testing.assert_allclose(np.asarray(out.flat), ref_flat, atol=1e-5)
        us = common.time_fn(lambda: round_fn(state, batches_sh, key_sh),
                            warmup=warmup, iters=iters)
        split = sharded.boundary_row_split(graph_sh, n_shards)
        cut = sharded.cut_edge_stats(graph_sh, n_shards)
        cm = analysis.roundfuse_cost_model(
            n_agents=n_sh, d=d_sh, optimizer="sgd", codec=False,
            param_bytes=4, n_shards=n_shards,
            boundary_rows_per_shard=split["b_max"],
            num_halo_rounds=cut["num_halo_rounds"])
        row = {"section": "sharded", "n_agents": n_sh, "n_shards": n_shards,
               "d": d_sh, "h": shard_h, "us_per_round": round(us, 1),
               "max_abs_err": err,
               "boundary_rows_per_shard": cm["boundary_rows_per_shard"],
               "interior_rows_per_shard": cm["interior_rows_per_shard"],
               "num_halo_rounds": cm["num_halo_rounds"],
               "halo_bytes_full": cm["halo_bytes_full"],
               "halo_bytes_boundary": cm["halo_bytes_boundary"],
               "halo_payload_ratio": cm["halo_payload_ratio"],
               "predicted_overlap_fraction": cm["predicted_overlap_fraction"]}
        sharded_rows.append(row)
        common.emit(
            f"roundfuse_sharded_n{n_sh}_s{n_shards}", us,
            f"halo_ratio={cm['halo_payload_ratio']:.3f};"
            f"overlap={cm['predicted_overlap_fraction']:.3f}")

    # -- 4. block_d: the autotune-table sweep ------------------------------
    n_bd, d_bd = 32, block_d_sweep_d
    w_bd = jnp.asarray(MixingDistribution(
        topo.ring_graph(n_bd, k=2), scheme="metropolis").sample(
            jax.random.key(0)))
    x_bd = jax.random.normal(jax.random.key(5), (n_bd, d_bd), jnp.float32)
    g_bd = jax.random.normal(jax.random.key(6), (n_bd, d_bd), jnp.float32)
    block_rows = []
    chosen_bd = kernel_ops.autotune_block_d(d_bd, jnp.float32)
    for bd in (256, 512, 1024, 2048):
        fn = jax.jit(lambda w, x, g: kernel_ops.update_mix(
            w, x, g, 0.05, block_d=bd))
        us = common.time_fn(fn, w_bd, x_bd, g_bd, warmup=warmup, iters=iters)
        block_rows.append({"section": "block_d", "n_agents": n_bd, "d": d_bd,
                           "dtype": "float32", "block_d": bd,
                           "us_per_call": round(us, 1),
                           "chosen": bd == chosen_bd})
        common.emit(f"roundfuse_blockd_{bd}_d{d_bd}", us,
                    f"chosen={bd == chosen_bd}")
    table_rows = [{"section": "block_d_table", "d": d, "dtype": dt,
                   "block_d": kernel_ops.autotune_block_d(d, jnp.dtype(dt))}
                  for d in (1 << 12, 1 << 17, 1 << 20)
                  for dt in ("float32", "bfloat16")]

    head = [r for r in rows if r["section"] == "headline"]
    acceptance = {
        "equivalence_checked_fused_vs_unfused": True,
        "max_abs_err_engine": max_err_engine,
        "sgd_pass_ratio": next(r["pass_ratio"] for r in rows
                               if r["optimizer"] == "sgd"
                               and not r["codec"]),
        "headline_speedup_sgd": next(r["speedup"] for r in head
                                     if r["optimizer"] == "sgd"),
        "headline_speedup_momentum": next(r["speedup"] for r in head
                                          if r["optimizer"] == "momentum"),
        "sharded_max_abs_err": max(r["max_abs_err"] for r in sharded_rows),
        "note": ("CPU: the engine rows time the real fused executor (Pallas "
                 "in interpret mode at the tiny fig4 D); the headline rows "
                 "time the identical XLA sparse-ELL math with the dispatch "
                 "split as the only variable, because interpret mode cannot "
                 "stream 2^30 elements — the transferable evidence is the "
                 "exact passes_/pass_bytes columns "
                 "(analysis.roundfuse_cost_model) plus the measured "
                 "one-dispatch-vs-two speedup at the 4 GiB buffer"),
    }
    out = {"workload": "fused update+gossip round: one pass over the flat "
                       "(n, D) buffer vs the unfused two-pass body, plus "
                       "the sharded boundary-halo/interior-compute overlap",
           "backend": jax.default_backend(), "smoke": smoke,
           "devices": N_DEVICES,
           "rows": rows, "sharded_rows": sharded_rows,
           "block_d_rows": block_rows + table_rows,
           "acceptance": acceptance}
    name = "BENCH_roundfuse.smoke.json" if smoke else "BENCH_roundfuse.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv(
        "bench_roundfuse.csv",
        ["section", "impl_or_shards", "optimizer", "codec", "n_agents", "d",
         "us_fused", "us_unfused", "speedup", "pass_ratio"],
        [(r["section"], r["impl"], r["optimizer"], r["codec"], r["n_agents"],
          r["d"], r["us_fused"], r["us_unfused"], r["speedup"],
          r["pass_ratio"]) for r in rows]
        + [(r["section"], r["n_shards"], "sgd", False, r["n_agents"], r["d"],
            r["us_per_round"], "", "", r["halo_payload_ratio"])
           for r in sharded_rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / few iterations for CI")
    p.add_argument("--child", action="store_true",
                   help="internal: run the benchmark body (assumes the "
                        "forced-device XLA flag is already set)")
    args = p.parse_args()
    if args.child:
        _child_main(smoke=args.smoke)
    else:
        print("name,us_per_call,derived")
        main(smoke=args.smoke)
