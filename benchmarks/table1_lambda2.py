"""Paper Table 1: |λ₂|² for geographic and Erdős–Rényi graph families.

Laplacian (best-constant) weights [26], 10 independent graph draws per
cell, n ∈ {10, 20, 40}; geographic r ∈ {0.35, 0.5, 0.65}, ER
p ∈ {0.3, 0.5, 0.7}.  Validates the paper's reference values to ±0.15
(graph draws are random; the paper reports its own 10-draw averages) and
the two structural claims: |λ₂|² < 0.9 everywhere (⇒ α < 9), and
connectivity ↑ ⇒ |λ₂|² ↓ within every family/size.
"""

from __future__ import annotations

import time

import numpy as np

from benchmarks import common
from repro.core import topology as topo

PAPER = {  # Table 1 of the paper
    ("geo", 0.35): {10: 0.78, 20: 0.87, 40: 0.83},
    ("geo", 0.50): {10: 0.70, 20: 0.64, 40: 0.56},
    ("geo", 0.65): {10: 0.41, 20: 0.33, 40: 0.34},
    ("er", 0.3): {10: 0.70, 20: 0.62, 40: 0.40},
    ("er", 0.5): {10: 0.42, 20: 0.29, 40: 0.17},
    ("er", 0.7): {10: 0.25, 20: 0.13, 40: 0.083},
}
SEEDS = 10


def _cell(kind: str, param: float, n: int, seeds: int) -> float:
    """One Table-1 cell: all ``seeds`` graph draws' |λ₂|² in one batched
    eigendecomposition (stacked Ws → topo.lambda2_hat_fixed_batched)
    instead of one call per seed; the batch is bit-identical to the
    per-seed loop it replaced, so the printed table is unchanged."""
    graphs = [topo.geographic_graph(n, param, seed=s) if kind == "geo"
              else topo.erdos_renyi_graph(n, param, seed=s)
              for s in range(seeds)]
    ws = np.stack([topo.laplacian_weights(g) for g in graphs])
    return float(np.mean(topo.lambda2_hat_fixed_batched(ws)))


def run_experiment(seeds: int = SEEDS):
    rows, table = [], {}
    for (kind, param), ref_by_n in PAPER.items():
        for n, ref in ref_by_n.items():
            val = _cell(kind, param, n, seeds)
            table[(kind, param, n)] = val
            rows.append((kind, param, n, round(val, 4), ref,
                         round(abs(val - ref), 4)))
    return rows, table


def validate(table: dict) -> list[str]:
    checks = []
    worst = max((abs(v - PAPER[(k, p)][n]), (k, p, n))
                for (k, p, n), v in table.items())
    checks.append(f"T1 max |ours − paper| = {worst[0]:.3f} at {worst[1]}: "
                  f"{'PASS' if worst[0] < 0.15 else 'FAIL'} (tol 0.15)")
    allow = all(v < 0.9 for v in table.values())
    checks.append(f"T2 all |λ₂|² < 0.9 (⇒ α < 9): "
                  f"{'PASS' if allow else 'FAIL'}")
    mono = True
    for kind, params in (("geo", (0.35, 0.5, 0.65)), ("er", (0.3, 0.5, 0.7))):
        for n in (10, 20, 40):
            seq = [table[(kind, p, n)] for p in params]
            mono &= seq[0] > seq[1] > seq[2]
    checks.append(f"T3 connectivity↑ ⇒ |λ₂|²↓ in every family/size: "
                  f"{'PASS' if mono else 'FAIL'}")
    return checks


def main(seeds: int = SEEDS) -> None:
    t0 = time.perf_counter()
    rows, table = run_experiment(seeds)
    common.write_csv("table1_lambda2.csv",
                     ["family", "param", "n", "lambda2_sq", "paper",
                      "abs_diff"], rows)
    checks = validate(table)
    for c in checks:
        print("#", c)
    n_pass = sum("PASS" in c for c in checks)
    common.emit("table1_lambda2", (time.perf_counter() - t0) * 1e6,
                f"claims_pass={n_pass}/{len(checks)}")


if __name__ == "__main__":
    p = common.figure_arg_parser(__doc__, seeds=SEEDS)
    args = p.parse_args()
    main(seeds=3 if args.smoke else args.seeds)
