"""Benchmark entry point — one experiment per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines (plus '#'-prefixed claim-check
commentary) and writes full curves/tables under results/benchmarks/.

  fig4_convergence — Fig. 4: FedDec vs FedAvg, 2 graphs × H∈{10,100}
  table1_lambda2   — Table 1: |λ₂|² across graph families
  fig2_alpha       — Fig. 2: α(|λ̂₂|) + Lemma 3 contraction check
  theory_check     — Theorem 1 bound vs measured trajectory
  bench_kernels    — kernel micro-benchmarks + Pallas validation
  bench_fused      — fused lax.scan round executor vs per-step dispatch
  bench_gossip     — gossip impls (dense/pallas/sparse × tree/flat layout)
  bench_sharded    — agent-sharded flat engine weak-scaling (shard_map
                     psum_scatter vs ppermute halo, 1–8 host devices)
  bench_compress   — compressed gossip (EF codecs, compressed halo bytes,
                     fused quant/dequant-mix kernels, linreg convergence)
  bench_sweep      — batched sweep engine vs the per-seed Python loop
                     (one-compile lattice execution at fig4 shapes)
  bench_population — cohort-sampled population engine (n_total up to 1e6:
                     flat peak-device bytes, streaming overlap, cohort
                     bit-identity vs the flat sparse engine)
  bench_delta      — delta-parameterized state (DeltaStore bytes vs the
                     dense store, rank=full bit-identity, batched
                     personalized serving vs the naive per-agent loop)
  bench_roundfuse  — fused update+gossip round (kernels/update_mix.py):
                     buffer-pass bytes + wall-clock fused vs unfused at
                     fig4 and n=1024, D=2^20, sharded boundary-halo
                     overlap rows, block_d autotune sweep
  ablation_server  — beyond-paper: §5 conjecture (server vs pure gossip)
  roofline         — aggregates results/dryrun into the §Roofline table
"""

import argparse


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", action="store_true",
                   help="reduced T/seeds for CI")
    p.add_argument("--only", default=None)
    args = p.parse_args()

    from benchmarks import (ablation_server, bench_compress, bench_delta,
                            bench_fused, bench_gossip, bench_kernels,
                            bench_population, bench_roundfuse, bench_sharded,
                            bench_sweep, fig2_alpha, fig4_convergence,
                            roofline, table1_lambda2, theory_check)
    jobs = {
        "table1_lambda2": lambda: table1_lambda2.main(
            seeds=3 if args.quick else 10),
        "fig2_alpha": fig2_alpha.main,
        "fig4_convergence": lambda: fig4_convergence.main(
            t_steps=1500 if args.quick else 5000,
            seeds=3 if args.quick else 10),
        "theory_check": theory_check.main,
        "bench_kernels": bench_kernels.main,
        "bench_fused": lambda: bench_fused.main(quick=args.quick),
        "bench_gossip": lambda: bench_gossip.main(smoke=args.quick),
        "bench_sharded": lambda: bench_sharded.main(smoke=args.quick),
        "bench_compress": lambda: bench_compress.main(smoke=args.quick),
        "bench_sweep": lambda: bench_sweep.main(smoke=args.quick),
        "bench_population": lambda: bench_population.main(smoke=args.quick),
        "bench_delta": lambda: bench_delta.main(smoke=args.quick),
        "bench_roundfuse": lambda: bench_roundfuse.main(smoke=args.quick),
        "ablation_server": lambda: ablation_server.main(
            t_steps=1500 if args.quick else 3000,
            seeds=3 if args.quick else 6),
        "roofline": roofline.main,
    }
    print("name,us_per_call,derived")
    for name, job in jobs.items():
        if args.only and args.only != name:
            continue
        try:
            job()
        except Exception as e:  # noqa: BLE001 — keep the suite running
            print(f"{name},0,ERROR:{type(e).__name__}:{e}")


if __name__ == "__main__":
    main()
