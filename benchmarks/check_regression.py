"""Perf-regression guard for the CI bench-smoke job.

Freshly-produced smoke benchmark JSONs (written to
results/benchmarks/BENCH_*.smoke.json — smoke runs never touch the
committed full-run files) are diffed against the committed baselines
(results/benchmarks/BENCH_gossip.json / BENCH_sharded.json).  Smoke and
committed runs use different shapes (tiny D, fewer leaves), so raw
wall-clock is never compared; the guard pins the *structural* perf
evidence instead:

  * exact   — ``dispatches_per_gossip`` (whole-buffer impls are 1 dispatch,
    leaf-wise impls one per leaf) and the ``model_bytes``/``model_flops``
    columns, recomputed from each row's own (n, d, leaves, graph) through
    launch.analysis.gossip_cost_model: the emitted rows and the cost model
    must never drift apart, in the fresh run or the committed baseline;
  * ordering (generous tolerance) — the like-for-like kernel evidence that
    justifies the flat engine: the SAME Pallas kernel applied leaf-wise
    must stay slower than one whole-buffer call at the largest n
    (committed baseline shows 5–9×; the guard only requires >1.1× so CPU
    runner noise cannot flake it);
  * sharded — BENCH_sharded.json rows are well-formed, the ppermute-halo
    collective bytes stay at or below the dense psum_scatter's for every
    multi-shard configuration, and every timed config passed its
    equivalence check against the unsharded dense mix.
  * compress — BENCH_compress.json halo rows' collective-byte columns are
    exact against analysis.compressed_halo_cost_model, the int8 halo moves
    ≤ 0.30× the f32 halo's bytes on every multi-shard config (with a
    vacuity proof that such configs exist), the payload ordering
    int8 < bf16 < f32 and the fused-kernel < unfused-kernel streamed-byte
    ordering hold exactly, and the recorded int8+EF linreg run tracked the
    uncompressed final loss within 5%.
  * sweep — BENCH_sweep.json rows' dispatch-count and state/stream-byte
    columns are exact against analysis.sweep_cost_model, the batched
    lattice stays faster than the per-seed windowed loop on every row
    (generous 1.5× floor so CPU-runner noise cannot flake the smoke job),
    every timed config passed its slice-equivalence check against the
    single-run flat engine, and the committed (non-smoke) baseline shows
    the ≥5× acceptance speedup at the fig4 seed count.  The composed
    sharded-sweep rows (R runs × s agent shards as one shard_map program)
    are exact against analysis.sharded_sweep_cost_model, every row passed
    its per-run slice check at 1e-5, and the per-device state/stream
    bytes stay constant across the weak-scaling shard grid.
  * population — BENCH_population.json rows' byte columns are exact
    against analysis.population_cost_model, ``peak_device_bytes`` is
    IDENTICAL across the whole n_total grid (the cohort-streaming
    invariant: device residency has no n_total term; the committed
    baseline must reach n_total = 1e6), the streaming-overlap pipeline
    bound stays ≥ 1.2× (the measured wall-clock ratio additionally ≥ 1.2×
    when the recording host had > 1 CPU — single-core runners time-slice
    XLA and host work), and the n_total == cohort trajectory stayed
    bit-identical to the flat sparse engine.

  * delta — BENCH_delta.json store rows' byte columns are exact against
    analysis.delta_cost_model (and every materialized store's *measured*
    nbytes equals the model exactly — the memmap layout and the analytic
    row must never drift apart), the topk delta store stays ≤ 0.25× the
    dense population store at the largest n_total (committed baseline must
    reach 1e6 with the store actually materialized), the rank=full engine
    trajectory stayed bit-identical to the flat engine (max_abs_err == 0.0
    with an exactly-zero EF residual — the PR 4/5/6 gate), the DeltaStore
    full-kind round-trip is bitwise, and batched personalized serving
    decoded the same tokens as the naive per-request loop while beating
    its tokens/sec.

  * roundfuse — BENCH_roundfuse.json rows' pass/byte columns are exact
    against analysis.roundfuse_cost_model, the fused sgd body streams
    ≤ 0.6× the unfused body's buffer-pass bytes (with a vacuity proof
    such rows exist), every fused row passed its fused-vs-unfused
    equivalence check at 1e-5, the committed headline (n=1024, D=2^20)
    one-dispatch speedup stays ≥ 1.1× with a minimum-wall-clock proof the
    4 GiB buffer was actually streamed, and the sharded boundary-halo
    rows' split/overlap columns are exact against the model recomputed
    from the contract ring(n, k=2) graph.

Run (what ci.yml does):
  PYTHONPATH=src python -m benchmarks.check_regression \\
      --baseline-gossip results/benchmarks/BENCH_gossip.json \\
      --fresh-gossip results/benchmarks/BENCH_gossip.smoke.json \\
      --baseline-sharded results/benchmarks/BENCH_sharded.json \\
      --fresh-sharded results/benchmarks/BENCH_sharded.smoke.json \\
      --baseline-compress results/benchmarks/BENCH_compress.json \\
      --fresh-compress results/benchmarks/BENCH_compress.smoke.json \\
      --baseline-delta results/benchmarks/BENCH_delta.json \\
      --fresh-delta results/benchmarks/BENCH_delta.smoke.json
"""

from __future__ import annotations

import argparse
import json

from repro.core import sharded as sharded_lib
from repro.core import topology as topo
from repro.launch import analysis

ORDERING_MARGIN = 1.1  # generous: baseline like-for-like ratio is 5-9x

REQUIRED_GOSSIP = {"impl", "n_agents", "d", "num_leaves", "us_per_call",
                   "dispatches_per_gossip", "model_bytes", "model_flops"}
REQUIRED_SHARDED = {"impl", "n_agents", "n_shards", "agents_per_device", "d",
                    "us_per_call", "per_device_bytes", "collective_bytes",
                    "num_cut_edges", "num_halo_rounds"}
REQUIRED_COMPRESS_HALO = {"compress", "n_agents", "n_shards", "d",
                          "us_per_call", "row_payload_bytes",
                          "collective_bytes", "payload_ratio_vs_f32",
                          "num_halo_rounds"}
REQUIRED_COMPRESS_KERNEL = {"impl", "n_agents", "d", "us_per_call",
                            "model_stream_bytes"}
REQUIRED_SWEEP = {"r_runs", "n_agents", "d", "t_steps", "h", "us_per_call",
                  "loop_us_per_call", "speedup", "dispatches_loop",
                  "dispatches_sweep", "state_bytes", "step_stream_bytes"}
REQUIRED_SHARDED_SWEEP = {"r_runs", "n_agents", "n_shards",
                          "agents_per_shard", "d", "t_steps", "h",
                          "us_per_call", "run_steps_per_s", "max_slice_err",
                          "state_bytes_per_device",
                          "step_stream_bytes_per_device",
                          "dense_collective_bytes", "halo_collective_bytes",
                          "num_halo_rounds", "dispatches_loop",
                          "dispatches_sweep"}
REQUIRED_POPULATION = {"n_total", "cohort_size", "d", "max_degree",
                       "steps_per_round", "us_per_round", "drains", "rounds",
                       "host_store_bytes", "upload_bytes_round",
                       "writeback_bytes_round", "hostdev_bytes_round",
                       "subgraph_edge_bytes_round", "peak_device_bytes",
                       "transfer_us_round"}
REQUIRED_POPULATION_OVERLAP = {"host_cpus", "sync_ms_per_round",
                               "overlap_ms_per_round", "device_stage_ms",
                               "host_stage_ms", "speedup_measured",
                               "speedup_pipeline_bound", "drains"}
REQUIRED_DELTA_ROW = {"n_total", "d", "delta", "delta_row_bytes",
                      "flat_row_bytes", "flat_store_bytes",
                      "delta_store_bytes", "store_ratio", "materialized",
                      "measured_store_bytes", "gather_us", "scatter_us"}
REQUIRED_DELTA_EQUIV = {"n_agents", "d", "h", "rounds", "max_abs_err",
                        "bit_identical", "residual_max_abs",
                        "store_roundtrip_exact"}
REQUIRED_DELTA_SERVING = {"arch", "d_flat", "batch", "prompt_len",
                          "new_tokens", "batched_tok_s", "naive_tok_s",
                          "speedup", "matches_naive"}
REQUIRED_ROUNDFUSE = {"section", "impl", "optimizer", "codec", "n_agents",
                      "d", "t_steps", "us_fused", "us_unfused", "speedup",
                      "max_abs_err", "passes_unfused", "passes_fused",
                      "unfused_pass_bytes", "fused_pass_bytes", "pass_ratio"}
REQUIRED_ROUNDFUSE_SHARDED = {"n_agents", "n_shards", "d", "h",
                              "us_per_round", "max_abs_err",
                              "boundary_rows_per_shard",
                              "interior_rows_per_shard", "num_halo_rounds",
                              "halo_bytes_full", "halo_bytes_boundary",
                              "halo_payload_ratio",
                              "predicted_overlap_fraction"}
REQUIRED_MESH2D = {"impl", "n_agents", "d", "h", "n_agent_shards",
                   "n_model_shards", "agents_per_device", "us_per_round",
                   "shard_bytes_measured", "state_bytes_per_device",
                   "gossip_collective_bytes", "model_collective_bytes",
                   "server_bytes_per_round", "num_halo_rounds"}
INT8_HALO_CEILING = 0.30  # acceptance: int8 halo bytes ≤ 0.30× f32 halo
SWEEP_SMOKE_MARGIN = 1.5   # generous: committed baseline shows 6-17x
SWEEP_ACCEPT_SPEEDUP = 5.0  # ISSUE acceptance at fig4 shapes (committed)
POPULATION_OVERLAP_FLOOR = 1.2    # acceptance: streaming overlap ≥ 1.2×
POPULATION_OVERLAP_SMOKE_FLOOR = 1.0  # relaxed: tiny smoke shapes
POPULATION_MAX_N = 1_000_000      # acceptance: committed run reaches 1e6
DELTA_STORE_CEILING = 0.25   # acceptance: topk delta store ≤ 0.25× dense
DELTA_MAX_N = 1_000_000      # acceptance: committed run reaches 1e6
DELTA_SERVING_FLOOR = 1.0    # batched personalized decode beats naive
ROUNDFUSE_PASS_CEILING = 0.6      # acceptance: fused sgd = 3/5 buffer passes
ROUNDFUSE_SPEEDUP_FLOOR = 1.1     # committed headline one-dispatch speedup
ROUNDFUSE_HEADLINE = (1024, 1 << 20)   # acceptance shape (n, D)
ROUNDFUSE_MIN_HEADLINE_US = 10_000.0   # anti-vacuity: 4 GiB streams aren't
#                                        sub-10ms on any host — a faster
#                                        "measurement" means the buffer
#                                        pass silently stopped happening


class RegressionError(AssertionError):
    pass


def _require(cond: bool, msg: str) -> None:
    if not cond:
        raise RegressionError(msg)


def check_gossip_doc(doc: dict, label: str) -> None:
    """Well-formedness + cost-model consistency + kernel-evidence ordering."""
    rows = doc.get("rows", [])
    _require(bool(rows), f"{label}: no benchmark rows")
    for row in rows:
        missing = REQUIRED_GOSSIP - set(row)
        _require(not missing, f"{label}: row missing {missing}: {row}")
        _require(row["us_per_call"] > 0, f"{label}: non-positive time {row}")
    impls = {r["impl"] for r in rows}
    _require({"tree_dense", "flat_dense", "flat_pallas",
              "flat_sparse"} <= impls, f"{label}: impl set shrank: {impls}")
    _require(bool(doc["acceptance"]["sparse_large_n"]),
             f"{label}: large-n sparse showcase rows vanished")

    # exact: every row's model_bytes/model_flops/dispatches must equal the
    # cost model recomputed at the row's own shape (bench_gossip contract:
    # the grid graph is ring(n, k=2), f32 params)
    for row in rows:
        n, d = row["n_agents"], row["d"]
        graph = topo.ring_graph(n, k=min(2, (n - 1) // 2 or 1))
        model = analysis.gossip_cost_model(
            n_agents=n, d=d, num_leaves=row["num_leaves"],
            num_directed_edges=2 * graph.num_edges, param_bytes=4)
        key = "flat_pallas" if row["impl"] == "tree_pallas" else row["impl"]
        cm = model.get(key, model["flat_dense"])
        for col, want in (("model_bytes", cm["bytes"]),
                          ("model_flops", cm["flops"])):
            _require(row[col] == want,
                     f"{label}: {row['impl']} n={n} {col} drifted: "
                     f"row={row[col]} cost-model={want}")
        want_disp = row["num_leaves"] if row["impl"].startswith("tree") else 1
        _require(row["dispatches_per_gossip"] == want_disp,
                 f"{label}: {row['impl']} dispatches_per_gossip="
                 f"{row['dispatches_per_gossip']} != {want_disp}")

    # ordering: leaf-wise vs whole-buffer application of the SAME kernel
    n_big = max(r["n_agents"] for r in rows)

    def us(impl):
        return next(r["us_per_call"] for r in rows
                    if r["impl"] == impl and r["n_agents"] == n_big)

    ratio = us("tree_pallas") / us("flat_pallas")
    _require(ratio > ORDERING_MARGIN,
             f"{label}: whole-buffer Pallas no longer beats leaf-wise at "
             f"n={n_big}: tree/flat ratio {ratio:.2f} <= {ORDERING_MARGIN}")
    print(f"[guard] {label}: {len(rows)} rows OK, "
          f"leafwise/whole-buffer pallas ratio {ratio:.1f}x at n={n_big}")


def check_sharded_doc(doc: dict, label: str) -> None:
    rows = doc.get("rows", [])
    _require(bool(rows), f"{label}: no benchmark rows")
    for row in rows:
        missing = REQUIRED_SHARDED - set(row)
        _require(not missing, f"{label}: row missing {missing}: {row}")
        _require(row["us_per_call"] > 0, f"{label}: non-positive time {row}")
    _require(bool(doc.get("round_rows")),
             f"{label}: fused sharded round rows vanished")
    _require(doc["acceptance"]["equivalence_checked_vs_unsharded_dense"],
             f"{label}: equivalence check was skipped")
    by_key = {(r["impl"], r["n_agents"], r["n_shards"]): r for r in rows}
    checked = 0
    for (impl, n, s), row in by_key.items():
        if impl != "sparse" or s == 1:
            continue
        dense = by_key.get(("dense", n, s))
        _require(dense is not None,
                 f"{label}: sparse row (n={n}, s={s}) has no dense partner")
        _require(row["collective_bytes"] <= dense["collective_bytes"],
                 f"{label}: halo collective bytes exceed dense psum_scatter "
                 f"at n={n}, s={s}: {row['collective_bytes']} > "
                 f"{dense['collective_bytes']}")
        checked += 1
    # vacuity guard: the halo-vs-dense byte evidence must actually exist —
    # a shrunk shard grid or a dropped impl must fail loudly, not pass
    _require(checked > 0,
             f"{label}: no multi-shard sparse rows to check — the halo "
             f"vs dense collective-byte evidence vanished")
    print(f"[guard] {label}: {len(rows)} rows OK, halo <= dense collective "
          f"bytes on {checked} multi-shard configs")


def check_mesh2d_doc(doc: dict, label: str) -> None:
    """2-D mesh evidence: exact 1/(A·M) per-device byte scaling (measured
    shard bytes == the analytic ``n/A · D/M · 4``, no tolerance) and every
    cost-model byte column equal to mesh2d_cost_model recomputed at the
    row's own shape — plus vacuity proofs that the model-sharded cells and
    the flat-engine equivalence check actually exist in the doc."""
    rows = doc.get("rows", [])
    _require(bool(rows), f"{label}: no benchmark rows")
    for row in rows:
        missing = REQUIRED_MESH2D - set(row)
        _require(not missing, f"{label}: row missing {missing}: {row}")
        _require(row["us_per_round"] > 0, f"{label}: non-positive time {row}")
        n, d = row["n_agents"], row["d"]
        a, m = row["n_agent_shards"], row["n_model_shards"]
        model = analysis.mesh2d_cost_model(
            n_agents=n, d=d, n_agent_shards=a, n_model_shards=m,
            num_halo_rounds=row["num_halo_rounds"],
            param_bytes=4)[row["impl"]]
        for col in ("state_bytes_per_device", "gossip_collective_bytes",
                    "model_collective_bytes", "server_bytes_per_round"):
            _require(row[col] == model[col],
                     f"{label}: {row['impl']} (A={a}, M={m}) {col} drifted: "
                     f"row={row[col]} cost-model={model[col]}")
        # the tentpole's memory law, exact: measured == n/A * D/M * 4
        _require(row["shard_bytes_measured"] == n // a * (d // m) * 4,
                 f"{label}: measured shard bytes {row['shard_bytes_measured']}"
                 f" != n/A * D/M * 4 at (A={a}, M={m})")
    impls = {r["impl"] for r in rows}
    _require({"dense", "sparse", "pallas"} <= impls,
             f"{label}: impl set shrank: {impls}")
    # vacuity: the model axis must actually be exercised — a grid reduced
    # to M = 1 cells would pass every formula above and prove nothing
    model_cells = [r for r in rows if r["n_model_shards"] > 1]
    _require(bool(model_cells),
             f"{label}: no M > 1 cells — the model axis vanished")
    _require(any(r["n_agent_shards"] > 1 for r in model_cells),
             f"{label}: no genuinely 2-D (A > 1, M > 1) cell")
    _require(bool(doc["acceptance"]["equivalence_checked_vs_flat"]),
             f"{label}: flat-engine equivalence check was skipped")
    _require(bool(doc["acceptance"]["am_way_scaling_exact"]),
             f"{label}: 1/(A*M) scaling law no longer exact")
    print(f"[guard] {label}: {len(rows)} rows OK, "
          f"{len(model_cells)} model-sharded cells, byte columns exact")


def check_mesh2d_baseline_vs_fresh(baseline: dict, fresh: dict) -> None:
    """The committed (A, M) grid and impl coverage must survive in the
    fresh run (a fresh run may add cells, never silently drop them)."""
    def cells(doc):
        return {(r["impl"], r["n_agent_shards"], r["n_model_shards"])
                for r in doc["rows"]}
    _require(cells(baseline) <= cells(fresh),
             f"fresh mesh2d run dropped cells: "
             f"{cells(baseline) - cells(fresh)}")


def check_roundfuse_doc(doc: dict, label: str) -> None:
    """Fused-round evidence: exact roundfuse_cost_model columns on every
    row, the fused sgd body at ≤ 0.6× the unfused buffer-pass bytes (with a
    vacuity proof such rows exist), fused-vs-unfused equivalence actually
    checked, the committed headline (n=1024, D=2^20) one-dispatch speedup,
    and well-formed sharded boundary-halo overlap rows."""
    rows = doc.get("rows", [])
    _require(bool(rows), f"{label}: no benchmark rows")
    for row in rows:
        missing = REQUIRED_ROUNDFUSE - set(row)
        _require(not missing, f"{label}: row missing {missing}: {row}")
        _require(row["us_fused"] > 0 and row["us_unfused"] > 0,
                 f"{label}: non-positive time {row}")
        _require(row["max_abs_err"] <= 1e-5,
                 f"{label}: fused-vs-unfused error {row['max_abs_err']} > "
                 f"1e-5 at {row['impl']}/{row['optimizer']}")
        # exact: every pass/byte column recomputed at the row's own shape
        model = analysis.roundfuse_cost_model(
            n_agents=row["n_agents"], d=row["d"],
            optimizer=row["optimizer"], codec=row["codec"], param_bytes=4)
        for col in ("passes_unfused", "passes_fused", "unfused_pass_bytes",
                    "fused_pass_bytes", "pass_ratio"):
            _require(row[col] == model[col],
                     f"{label}: {row['optimizer']} codec={row['codec']} "
                     f"{col} drifted: row={row[col]} "
                     f"cost-model={model[col]}")

    # the acceptance ceiling: fused sgd ≤ 0.6× unfused pass bytes, with a
    # vacuity proof that codec-free sgd rows actually exist (momentum and
    # codec rows have higher floors by construction — 5/7 and 13/17)
    sgd_rows = [r for r in rows if r["optimizer"] == "sgd"
                and not r["codec"]]
    _require(bool(sgd_rows),
             f"{label}: no codec-free sgd rows — the 0.6x pass-byte "
             f"evidence vanished")
    for row in sgd_rows:
        _require(row["pass_ratio"] <= ROUNDFUSE_PASS_CEILING,
                 f"{label}: sgd pass ratio {row['pass_ratio']} > "
                 f"{ROUNDFUSE_PASS_CEILING} at n={row['n_agents']}")
    impls = {r["impl"] for r in rows if r["section"] == "engine"}
    _require({"dense", "sparse", "pallas"} <= impls,
             f"{label}: engine impl coverage shrank: {impls}")
    _require({"sgd", "momentum"} <= {r["optimizer"] for r in rows},
             f"{label}: optimizer coverage shrank")
    _require(any(r["codec"] for r in rows),
             f"{label}: no codec (EF ef_mix kernel) rows")

    # the committed headline: the 4 GiB-buffer one-dispatch speedup must
    # exist at the acceptance shape and actually have streamed the buffer
    head = [r for r in rows if r["section"] == "headline"]
    _require(bool(head), f"{label}: headline rows vanished")
    if not doc.get("smoke"):
        hn, hd = ROUNDFUSE_HEADLINE
        at_shape = [r for r in head
                    if (r["n_agents"], r["d"]) == (hn, hd)]
        _require(bool(at_shape),
                 f"{label}: committed baseline has no headline row at "
                 f"n={hn}, D={hd}")
        for row in at_shape:
            # the speedup floor is pinned on the codec-free sgd row only:
            # that is the 0.60-ratio flagship the byte model promises the
            # most for.  momentum's 7->5 pass gap is real but small enough
            # that the CPU one-dispatch proxy measures ~1.0x there — the
            # row still ships (honest number, exact cost columns) without
            # a wall-clock floor.
            if row["optimizer"] == "sgd" and not row["codec"]:
                _require(row["speedup"] >= ROUNDFUSE_SPEEDUP_FLOOR,
                         f"{label}: headline sgd speedup "
                         f"{row['speedup']} < {ROUNDFUSE_SPEEDUP_FLOOR}")
            _require(row["us_fused"] >= ROUNDFUSE_MIN_HEADLINE_US,
                     f"{label}: headline fused call {row['us_fused']}us is "
                     f"implausibly fast for a {hn}x{hd} f32 buffer — the "
                     f"measurement went vacuous")
        _require(any(r["optimizer"] == "sgd" and not r["codec"]
                     for r in at_shape),
                 f"{label}: committed baseline lost the sgd headline row "
                 f"the speedup floor is pinned on")

    # sharded overlap rows: exact cost-model columns recomputed from the
    # bench contract graph (ring(n, k=2)), equivalence vs the flat round,
    # and a vacuity proof that multi-shard rows exist
    srows = doc.get("sharded_rows", [])
    _require(bool(srows), f"{label}: sharded overlap rows vanished")
    _require(any(r["n_shards"] > 1 for r in srows),
             f"{label}: no multi-shard overlap rows — the boundary-halo "
             f"evidence vanished")
    for row in srows:
        missing = REQUIRED_ROUNDFUSE_SHARDED - set(row)
        _require(not missing,
                 f"{label}: sharded row missing {missing}: {row}")
        _require(row["us_per_round"] > 0, f"{label}: non-positive {row}")
        _require(row["max_abs_err"] <= 1e-5,
                 f"{label}: sharded-vs-flat error {row['max_abs_err']} > "
                 f"1e-5 at s={row['n_shards']}")
        graph = topo.ring_graph(row["n_agents"], k=2)
        split = sharded_lib.boundary_row_split(graph, row["n_shards"])
        cut = sharded_lib.cut_edge_stats(graph, row["n_shards"])
        model = analysis.roundfuse_cost_model(
            n_agents=row["n_agents"], d=row["d"], optimizer="sgd",
            codec=False, param_bytes=4, n_shards=row["n_shards"],
            boundary_rows_per_shard=split["b_max"],
            num_halo_rounds=cut["num_halo_rounds"])
        for col in ("boundary_rows_per_shard", "interior_rows_per_shard",
                    "num_halo_rounds", "halo_bytes_full",
                    "halo_bytes_boundary", "halo_payload_ratio",
                    "predicted_overlap_fraction"):
            _require(row[col] == model[col],
                     f"{label}: sharded s={row['n_shards']} {col} drifted: "
                     f"row={row[col]} cost-model={model[col]}")
        n_local = row["n_agents"] // row["n_shards"]
        _require(row["boundary_rows_per_shard"]
                 + row["interior_rows_per_shard"] == n_local,
                 f"{label}: boundary+interior != n_local at "
                 f"s={row['n_shards']}")
        _require(row["halo_payload_ratio"] <= 1.0,
                 f"{label}: boundary halo moves MORE than the full block "
                 f"at s={row['n_shards']}")

    acc = doc["acceptance"]
    _require(bool(acc["equivalence_checked_fused_vs_unfused"]),
             f"{label}: fused-vs-unfused equivalence check was skipped")
    _require(acc["max_abs_err_engine"] <= 1e-5,
             f"{label}: engine equivalence error "
             f"{acc['max_abs_err_engine']} > 1e-5")
    _require(acc["sgd_pass_ratio"] <= ROUNDFUSE_PASS_CEILING,
             f"{label}: acceptance sgd pass ratio {acc['sgd_pass_ratio']} "
             f"> {ROUNDFUSE_PASS_CEILING}")
    print(f"[guard] {label}: {len(rows)} rows + {len(srows)} sharded rows "
          f"OK, sgd pass ratio {acc['sgd_pass_ratio']}, headline speedup "
          f"{acc['headline_speedup_sgd']}x (sgd) / "
          f"{acc['headline_speedup_momentum']}x (momentum)")


def check_roundfuse_baseline_vs_fresh(baseline: dict, fresh: dict) -> None:
    """The committed engine grid (impl, optimizer, codec) and the headline
    section must survive in the fresh run (smoke shrinks shapes, never
    coverage)."""
    def grid(doc):
        return {(r["impl"], r["optimizer"], r["codec"])
                for r in doc["rows"] if r["section"] == "engine"}
    _require(grid(baseline) <= grid(fresh),
             f"fresh roundfuse run dropped engine cells: "
             f"{grid(baseline) - grid(fresh)}")
    _require(any(r["section"] == "headline" for r in fresh["rows"]),
             "fresh roundfuse run dropped the headline section")


def check_compress_doc(doc: dict, label: str) -> None:
    """Compressed-gossip evidence: exact byte columns, int8 ≤ 0.30× f32
    halo, payload/kernel byte orderings, EF convergence — plus vacuity
    proofs that each class of evidence actually exists in the doc."""
    rows = doc.get("rows", [])
    _require(bool(rows), f"{label}: no benchmark rows")
    halo = [r for r in rows if r.get("section") == "halo"]
    kernels = [r for r in rows if r.get("section") == "kernel"]
    for row in halo:
        missing = REQUIRED_COMPRESS_HALO - set(row)
        _require(not missing, f"{label}: halo row missing {missing}: {row}")
        _require(row["us_per_call"] > 0, f"{label}: non-positive time {row}")
    for row in kernels:
        missing = REQUIRED_COMPRESS_KERNEL - set(row)
        _require(not missing,
                 f"{label}: kernel row missing {missing}: {row}")
    schemes = {r["compress"] for r in halo}
    _require({"none", "bf16", "int8"} <= schemes
             and any(s.startswith("topk:") for s in schemes),
             f"{label}: compressor coverage shrank: {schemes}")

    # exact: every halo row's byte columns must equal the cost model
    # recomputed at the row's own (n, s, d, rounds) — emitted rows and the
    # model must never drift apart
    for row in halo:
        model = analysis.compressed_halo_cost_model(
            n_agents=row["n_agents"], d=row["d"],
            n_shards=row["n_shards"],
            num_halo_rounds=row["num_halo_rounds"], param_bytes=4,
            schemes=(row["compress"],))[row["compress"]]
        for col in ("row_payload_bytes", "collective_bytes"):
            _require(row[col] == model[col],
                     f"{label}: {row['compress']} n_shards="
                     f"{row['n_shards']} {col} drifted: row={row[col]} "
                     f"cost-model={model[col]}")

    # int8 ≤ 0.30× f32 on every multi-shard config (+ vacuity proof)
    by_key = {(r["compress"], r["n_agents"], r["n_shards"]): r for r in halo}
    checked = 0
    for (scheme, n, s), row in by_key.items():
        if s == 1:
            continue
        base = by_key.get(("none", n, s))
        _require(base is not None,
                 f"{label}: {scheme} halo row (n={n}, s={s}) has no "
                 f"uncompressed partner")
        if scheme == "int8":
            ratio = row["collective_bytes"] / base["collective_bytes"]
            _require(ratio <= INT8_HALO_CEILING,
                     f"{label}: int8 halo bytes {ratio:.3f}× f32 exceed "
                     f"the {INT8_HALO_CEILING} ceiling at n={n}, s={s}")
            checked += 1
        if scheme == "bf16":
            _require(row["collective_bytes"] < base["collective_bytes"],
                     f"{label}: bf16 halo not below f32 at n={n}, s={s}")
    _require(checked > 0,
             f"{label}: no multi-shard int8 rows to check — the "
             f"compressed-halo byte evidence vanished")
    for (scheme, n, s), row in by_key.items():
        if scheme == "int8":
            bf = by_key.get(("bf16", n, s))
            _require(bf is not None
                     and row["collective_bytes"] < bf["collective_bytes"],
                     f"{label}: int8 < bf16 halo ordering broken at "
                     f"n={n}, s={s}")

    # kernel ordering on the streamed-byte model (wall-clock off-TPU is
    # interpret-mode noise): fused receive side < unfused XLA composition
    def kernel_bytes(impl):
        return next(r["model_stream_bytes"] for r in kernels
                    if r["impl"] == impl)

    _require(kernel_bytes("fused_dequant_mix")
             < kernel_bytes("xla_dequant_mix"),
             f"{label}: fused dequant-mix no longer streams fewer bytes "
             f"than the unfused composition")

    acc = doc["acceptance"]
    _require(bool(acc["identity_bit_identical_to_uncompressed"]),
             f"{label}: identity-compressor bit-identity check vanished")
    _require(bool(acc["equivalence_checked_sharded_vs_flat"]),
             f"{label}: sharded-vs-flat equivalence check was skipped")
    _require(acc["int8_halo_ratio_vs_f32"] <= INT8_HALO_CEILING,
             f"{label}: acceptance int8 halo ratio "
             f"{acc['int8_halo_ratio_vs_f32']} > {INT8_HALO_CEILING}")
    _require(abs(acc["int8_final_loss_ratio"] - 1.0) <= 0.05,
             f"{label}: int8+EF linreg final loss drifted "
             f"{acc['int8_final_loss_ratio']}× from uncompressed (>5%)")
    print(f"[guard] {label}: {len(halo)} halo + {len(kernels)} kernel rows "
          f"OK, int8 halo ratio {acc['int8_halo_ratio_vs_f32']}, "
          f"int8 linreg loss ratio {acc['int8_final_loss_ratio']}")


def check_sweep_doc(doc: dict, label: str) -> None:
    """Sweep-engine evidence: exact cost-model columns, batched ≥ threshold
    over the per-seed loop, slice equivalence actually checked."""
    rows = doc.get("rows", [])
    _require(bool(rows), f"{label}: no benchmark rows")
    for row in rows:
        missing = REQUIRED_SWEEP - set(row)
        _require(not missing, f"{label}: row missing {missing}: {row}")
        _require(row["us_per_call"] > 0, f"{label}: non-positive time {row}")
        model = analysis.sweep_cost_model(
            r_runs=row["r_runs"], n_agents=row["n_agents"], d=row["d"],
            t_steps=row["t_steps"], h=row["h"], param_bytes=4)
        for col in ("state_bytes", "step_stream_bytes", "dispatches_loop",
                    "dispatches_sweep"):
            _require(row[col] == model[col],
                     f"{label}: R={row['r_runs']} {col} drifted: "
                     f"row={row[col]} cost-model={model[col]}")
        _require(row["speedup"] > SWEEP_SMOKE_MARGIN,
                 f"{label}: batched sweep no longer beats the per-seed "
                 f"loop at R={row['r_runs']}: speedup {row['speedup']} <= "
                 f"{SWEEP_SMOKE_MARGIN}")
    acc = doc["acceptance"]
    _require(bool(acc["equivalence_checked_vs_flat"]),
             f"{label}: sweep-vs-flat slice equivalence check vanished")
    _require(acc["max_slice_err"] is not None
             and acc["max_slice_err"] <= 1e-5,
             f"{label}: sweep slice error {acc['max_slice_err']} > 1e-5")
    if not doc.get("smoke"):
        _require(acc["speedup_at_fig4_seeds"] >= SWEEP_ACCEPT_SPEEDUP,
                 f"{label}: committed baseline speedup at fig4 seeds "
                 f"{acc['speedup_at_fig4_seeds']} < {SWEEP_ACCEPT_SPEEDUP}")

    # sharded-sweep composition: weak-scaling rows at a fixed agents/shard
    # — exact cost-model columns, per-row slice equivalence, and per-device
    # footprint that does NOT grow as agents are added with devices
    srows = doc.get("sharded_rows", [])
    _require(bool(srows), f"{label}: sharded-sweep rows vanished")
    for row in srows:
        missing = REQUIRED_SHARDED_SWEEP - set(row)
        _require(not missing,
                 f"{label}: sharded row missing {missing}: {row}")
        _require(row["us_per_call"] > 0, f"{label}: non-positive time {row}")
        _require(row["max_slice_err"] <= 1e-5,
                 f"{label}: sharded-sweep slice error "
                 f"{row['max_slice_err']} > 1e-5 at s={row['n_shards']}")
        # bench_sweep contract: the weak-scaling graph is ring(n, k=1)
        stats = sharded_lib.cut_edge_stats(
            topo.ring_graph(row["n_agents"], k=1), row["n_shards"])
        model = analysis.sharded_sweep_cost_model(
            r_runs=row["r_runs"], n_agents=row["n_agents"], d=row["d"],
            n_shards=row["n_shards"],
            num_halo_rounds=stats["num_halo_rounds"],
            t_steps=row["t_steps"], h=row["h"], param_bytes=4)
        for col in ("state_bytes_per_device", "step_stream_bytes_per_device",
                    "dense_collective_bytes", "halo_collective_bytes",
                    "num_halo_rounds", "dispatches_loop",
                    "dispatches_sweep"):
            _require(row[col] == model[col],
                     f"{label}: sharded s={row['n_shards']} {col} drifted: "
                     f"row={row[col]} cost-model={model[col]}")
    _require(any(r["n_shards"] > 1 for r in srows),
             f"{label}: no multi-shard sharded-sweep rows — the composed "
             f"lowering evidence vanished")
    _require(len({r["agents_per_shard"] for r in srows}) == 1,
             f"{label}: weak scaling broken — agents_per_shard varies")
    for col in ("state_bytes_per_device", "step_stream_bytes_per_device"):
        _require(len({r[col] for r in srows}) == 1,
                 f"{label}: weak scaling broken — {col} varies across "
                 f"shard counts: {[r[col] for r in srows]}")
    sacc = acc["sharded_sweep"]
    _require(bool(sacc["equivalence_checked_vs_flat"]),
             f"{label}: sharded-sweep slice equivalence check vanished")
    _require(sacc["max_slice_err"] <= 1e-5,
             f"{label}: sharded-sweep acceptance slice error "
             f"{sacc['max_slice_err']} > 1e-5")
    print(f"[guard] {label}: {len(rows)} rows OK, speedups "
          f"{[r['speedup'] for r in rows]}, max slice err "
          f"{acc['max_slice_err']}; {len(srows)} sharded-sweep rows OK, "
          f"max slice err {sacc['max_slice_err']:.1e}")


def check_population_doc(doc: dict, label: str) -> None:
    """Population-engine evidence: exact cost-model columns, the flat
    peak-device-memory invariant across n_total, the streaming-overlap
    floor, and the cohort bit-identity acceptance."""
    rows = doc.get("rows", [])
    _require(bool(rows), f"{label}: no benchmark rows")
    for row in rows:
        missing = REQUIRED_POPULATION - set(row)
        _require(not missing, f"{label}: row missing {missing}: {row}")
        _require(row["us_per_round"] > 0, f"{label}: non-positive time {row}")
        # exact: every cost-model column recomputed at the row's own shape
        model = analysis.population_cost_model(
            n_total=row["n_total"], cohort_size=row["cohort_size"],
            d=row["d"], max_degree=row["max_degree"],
            h=row["steps_per_round"], param_bytes=4)
        for col, want in model.items():
            _require(row[col] == want,
                     f"{label}: n_total={row['n_total']} {col} drifted: "
                     f"row={row[col]} cost-model={want}")

    # the flat invariant: peak device bytes must be IDENTICAL across all
    # n_total rows (cohort-bounded residency, no n_total term) — with a
    # vacuity proof that the grid actually spans multiple n_total
    n_totals = sorted({r["n_total"] for r in rows})
    _require(len(n_totals) >= 2,
             f"{label}: n_total grid shrank to {n_totals} — the flat "
             f"peak-memory evidence needs at least two scales")
    peaks = {r["peak_device_bytes"] for r in rows}
    _require(len(peaks) == 1,
             f"{label}: peak_device_bytes varies across n_total: "
             f"{sorted(peaks)} — the streaming invariant broke")
    stores = [r["host_store_bytes"]
              for r in sorted(rows, key=lambda r: r["n_total"])]
    _require(stores == sorted(stores) and len(set(stores)) == len(stores),
             f"{label}: host_store_bytes not increasing with n_total: "
             f"{stores}")

    # streaming overlap: the pipeline bound (measured stage times) carries
    # the floor everywhere; the wall-clock ratio additionally when the
    # recording machine had >1 CPU (a single-core runner time-slices XLA
    # compute and host numpy, capping measured overlap at ~1.0×)
    ov = doc.get("overlap", {})
    missing = REQUIRED_POPULATION_OVERLAP - set(ov)
    _require(not missing, f"{label}: overlap record missing {missing}")
    floor = POPULATION_OVERLAP_SMOKE_FLOOR if doc.get("smoke") \
        else POPULATION_OVERLAP_FLOOR
    _require(ov["speedup_pipeline_bound"] >= floor,
             f"{label}: overlap pipeline bound "
             f"{ov['speedup_pipeline_bound']} < {floor}")
    if not doc.get("smoke") and ov["host_cpus"] > 1:
        _require(ov["speedup_measured"] >= POPULATION_OVERLAP_FLOOR,
                 f"{label}: measured overlap speedup "
                 f"{ov['speedup_measured']} < {POPULATION_OVERLAP_FLOOR} "
                 f"on a {ov['host_cpus']}-CPU host")

    eq = doc.get("equivalence", {})
    _require(bool(eq.get("bit_identical")) and eq.get("max_abs_err") == 0.0,
             f"{label}: cohort bit-identity vs the flat sparse engine "
             f"broke: {eq}")
    _require(eq.get("n_total") == eq.get("cohort_size"),
             f"{label}: equivalence section no longer runs the "
             f"n_total == cohort_size anchor: {eq}")
    if not doc.get("smoke"):
        _require(max(n_totals) >= POPULATION_MAX_N,
                 f"{label}: committed baseline tops out at "
                 f"n_total={max(n_totals)} < {POPULATION_MAX_N}")
    print(f"[guard] {label}: {len(rows)} rows OK "
          f"(n_total {n_totals}, peak_device_bytes {peaks.pop():.0f} flat), "
          f"overlap bound {ov['speedup_pipeline_bound']}x "
          f"(measured {ov['speedup_measured']}x on {ov['host_cpus']} cpu), "
          f"bit-identity max_abs_err {eq['max_abs_err']}")


def check_delta_doc(doc: dict, label: str) -> None:
    """Delta-parameterization evidence: exact byte columns (analytic AND
    measured), the ≤ 0.25× topk store ceiling, the rank=full bit-identity
    gate, and the batched-serving ordering."""
    rows = doc.get("rows", [])
    _require(bool(rows), f"{label}: no benchmark rows")
    for row in rows:
        missing = REQUIRED_DELTA_ROW - set(row)
        _require(not missing, f"{label}: row missing {missing}: {row}")
        # exact: every analytic column recomputed at the row's own shape
        model = analysis.delta_cost_model(
            n_total=row["n_total"], d=row["d"], delta=row["delta"])
        for col, want in model.items():
            _require(row[col] == want,
                     f"{label}: delta={row['delta']} n_total="
                     f"{row['n_total']} {col} drifted: row={row[col]} "
                     f"cost-model={want}")
        if row["materialized"]:
            # the memmap layout IS the byte model: measured == analytic
            _require(row["measured_store_bytes"]
                     == model["delta_store_bytes"],
                     f"{label}: delta={row['delta']} n_total="
                     f"{row['n_total']} measured store bytes "
                     f"{row['measured_store_bytes']} != analytic "
                     f"{model['delta_store_bytes']}")
            _require(row["gather_us"] > 0 and row["scatter_us"] > 0,
                     f"{label}: non-positive gather/scatter time: {row}")
    kinds = {r["delta"].split(":")[0] for r in rows}
    _require({"topk", "lowrank", "full"} <= kinds,
             f"{label}: delta-kind coverage shrank: {kinds}")

    # the acceptance column: the topk store ≤ 0.25× the dense population
    # store at the largest n_total, with the store actually materialized
    # there (measured bytes, not just the model)
    max_n = max(r["n_total"] for r in rows)
    topk_rows = [r for r in rows
                 if r["n_total"] == max_n and r["delta"].startswith("topk:")]
    _require(bool(topk_rows),
             f"{label}: no topk row at the largest n_total={max_n}")
    for row in topk_rows:
        _require(row["store_ratio"] <= DELTA_STORE_CEILING,
                 f"{label}: topk store ratio {row['store_ratio']:.4f} > "
                 f"{DELTA_STORE_CEILING} at n_total={max_n}")
        _require(row["materialized"],
                 f"{label}: the acceptance topk store at n_total={max_n} "
                 f"was never materialized — the measured-byte evidence "
                 f"vanished")

    # the PR 4/5/6 gate: rank=full trajectory bit-identical, residual
    # exactly zero, store round-trip bitwise
    eq = doc.get("equivalence", {})
    missing = REQUIRED_DELTA_EQUIV - set(eq)
    _require(not missing, f"{label}: equivalence record missing {missing}")
    _require(bool(eq["bit_identical"]) and eq["max_abs_err"] == 0.0,
             f"{label}: rank=full bit-identity broke: {eq}")
    _require(eq["residual_max_abs"] == 0.0,
             f"{label}: rank=full EF residual is nonzero "
             f"({eq['residual_max_abs']}) — the lossless anchor leaks")
    _require(bool(eq["store_roundtrip_exact"]),
             f"{label}: DeltaStore full-kind round-trip lost bitwise "
             f"exactness")

    # serving: identical tokens, batched beats the naive per-request loop
    sv = doc.get("serving", {})
    missing = REQUIRED_DELTA_SERVING - set(sv)
    _require(not missing, f"{label}: serving record missing {missing}")
    _require(bool(sv["matches_naive"]),
             f"{label}: batched personalized decode diverged from the "
             f"naive per-request loop")
    _require(sv["speedup"] > DELTA_SERVING_FLOOR,
             f"{label}: batched personalized decode no longer beats naive "
             f"per-agent serving: {sv['speedup']} <= {DELTA_SERVING_FLOOR}")

    acc = doc.get("acceptance", {})
    _require(bool(acc.get("rank_full_bit_identical"))
             and acc.get("max_abs_err") == 0.0,
             f"{label}: acceptance bit-identity record broke: {acc}")
    _require(acc.get("store_ratio_at_max_n", 1.0) <= DELTA_STORE_CEILING,
             f"{label}: acceptance store ratio "
             f"{acc.get('store_ratio_at_max_n')} > {DELTA_STORE_CEILING}")
    if not doc.get("smoke"):
        _require(max_n >= DELTA_MAX_N,
                 f"{label}: committed baseline tops out at "
                 f"n_total={max_n} < {DELTA_MAX_N}")
    print(f"[guard] {label}: {len(rows)} rows OK, topk ratio "
          f"{topk_rows[0]['store_ratio']:.4f} at n_total={max_n}, "
          f"bit-identity max_abs_err {eq['max_abs_err']}, serving "
          f"{sv['speedup']}x over naive")


def check_delta_baseline_vs_fresh(baseline: dict, fresh: dict) -> None:
    """Smoke runs shrink the n_total grid by design; the delta-kind
    coverage and the bit-identity anchor must survive."""
    base_deltas = {r["delta"] for r in baseline["rows"]}
    new_deltas = {r["delta"] for r in fresh["rows"]}
    _require(base_deltas <= new_deltas,
             f"fresh delta run dropped schemes: {base_deltas - new_deltas}")
    _require(bool(fresh.get("equivalence", {}).get("bit_identical")),
             "fresh delta run lost the rank=full bit-identity anchor")


def check_population_baseline_vs_fresh(baseline: dict, fresh: dict) -> None:
    """Smoke runs shrink the n_total grid by design; the fixed-cohort
    contract and the equivalence anchor must survive."""
    base_cohorts = {r["cohort_size"] for r in baseline["rows"]}
    new_cohorts = {r["cohort_size"] for r in fresh["rows"]}
    _require(base_cohorts == new_cohorts,
             f"fresh population run changed the fixed cohort: "
             f"{base_cohorts} -> {new_cohorts}")
    _require(bool(fresh.get("equivalence", {}).get("bit_identical")),
             "fresh population run lost the bit-identity anchor")


def check_sweep_baseline_vs_fresh(baseline: dict, fresh: dict) -> None:
    """The fig4-seed-count row (the acceptance shape) must survive."""
    fig4_r = baseline["acceptance"]["fig4_shape"]["seeds"]
    _require(any(r["r_runs"] == fig4_r for r in fresh["rows"]),
             f"fresh sweep run dropped the fig4-shape row (R={fig4_r})")


def check_compress_baseline_vs_fresh(baseline: dict, fresh: dict) -> None:
    base = {r["compress"] for r in baseline["rows"]
            if r.get("section") == "halo"}
    new = {r["compress"] for r in fresh["rows"] if r.get("section") == "halo"}
    _require(base <= new,
             f"fresh compress run dropped schemes: {base - new}")


def check_baseline_vs_fresh(baseline: dict, fresh: dict) -> None:
    """The committed baseline's impl coverage must survive in the fresh run
    (a fresh run may add impls, never silently drop them)."""
    base_impls = {r["impl"] for r in baseline["rows"]}
    fresh_impls = {r["impl"] for r in fresh["rows"]}
    _require(base_impls <= fresh_impls,
             f"fresh run dropped impls: {base_impls - fresh_impls}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline-gossip", required=True)
    p.add_argument("--fresh-gossip", required=True)
    p.add_argument("--baseline-sharded", default=None,
                   help="optional: committed BENCH_sharded.json baseline")
    p.add_argument("--fresh-sharded", required=True)
    p.add_argument("--baseline-compress", default=None,
                   help="optional: committed BENCH_compress.json baseline")
    p.add_argument("--fresh-compress", default=None,
                   help="fresh BENCH_compress[.smoke].json to check")
    p.add_argument("--baseline-sweep", default=None,
                   help="optional: committed BENCH_sweep.json baseline")
    p.add_argument("--fresh-sweep", default=None,
                   help="fresh BENCH_sweep[.smoke].json to check")
    p.add_argument("--baseline-population", default=None,
                   help="optional: committed BENCH_population.json baseline")
    p.add_argument("--fresh-population", default=None,
                   help="fresh BENCH_population[.smoke].json to check")
    p.add_argument("--baseline-delta", default=None,
                   help="optional: committed BENCH_delta.json baseline")
    p.add_argument("--fresh-delta", default=None,
                   help="fresh BENCH_delta[.smoke].json to check")
    p.add_argument("--baseline-mesh2d", default=None,
                   help="optional: committed BENCH_mesh2d.json baseline")
    p.add_argument("--fresh-mesh2d", default=None,
                   help="fresh BENCH_mesh2d[.smoke].json to check")
    p.add_argument("--baseline-roundfuse", default=None,
                   help="optional: committed BENCH_roundfuse.json baseline")
    p.add_argument("--fresh-roundfuse", default=None,
                   help="fresh BENCH_roundfuse[.smoke].json to check")
    args = p.parse_args()

    with open(args.baseline_gossip) as f:
        baseline = json.load(f)
    with open(args.fresh_gossip) as f:
        fresh = json.load(f)
    with open(args.fresh_sharded) as f:
        fresh_sharded = json.load(f)

    check_gossip_doc(baseline, "baseline BENCH_gossip")
    check_gossip_doc(fresh, "fresh BENCH_gossip")
    check_baseline_vs_fresh(baseline, fresh)
    check_sharded_doc(fresh_sharded, "fresh BENCH_sharded")
    if args.baseline_sharded:
        with open(args.baseline_sharded) as f:
            check_sharded_doc(json.load(f), "baseline BENCH_sharded")
    if args.fresh_compress:
        with open(args.fresh_compress) as f:
            fresh_compress = json.load(f)
        check_compress_doc(fresh_compress, "fresh BENCH_compress")
        if args.baseline_compress:
            with open(args.baseline_compress) as f:
                baseline_compress = json.load(f)
            check_compress_doc(baseline_compress, "baseline BENCH_compress")
            check_compress_baseline_vs_fresh(baseline_compress,
                                             fresh_compress)
    if args.fresh_sweep:
        with open(args.fresh_sweep) as f:
            fresh_sweep = json.load(f)
        check_sweep_doc(fresh_sweep, "fresh BENCH_sweep")
        if args.baseline_sweep:
            with open(args.baseline_sweep) as f:
                baseline_sweep = json.load(f)
            check_sweep_doc(baseline_sweep, "baseline BENCH_sweep")
            check_sweep_baseline_vs_fresh(baseline_sweep, fresh_sweep)
    if args.fresh_population:
        with open(args.fresh_population) as f:
            fresh_population = json.load(f)
        check_population_doc(fresh_population, "fresh BENCH_population")
        if args.baseline_population:
            with open(args.baseline_population) as f:
                baseline_population = json.load(f)
            check_population_doc(baseline_population,
                                 "baseline BENCH_population")
            check_population_baseline_vs_fresh(baseline_population,
                                               fresh_population)
    if args.fresh_delta:
        with open(args.fresh_delta) as f:
            fresh_delta = json.load(f)
        check_delta_doc(fresh_delta, "fresh BENCH_delta")
        if args.baseline_delta:
            with open(args.baseline_delta) as f:
                baseline_delta = json.load(f)
            check_delta_doc(baseline_delta, "baseline BENCH_delta")
            check_delta_baseline_vs_fresh(baseline_delta, fresh_delta)
    if args.fresh_mesh2d:
        with open(args.fresh_mesh2d) as f:
            fresh_mesh2d = json.load(f)
        check_mesh2d_doc(fresh_mesh2d, "fresh BENCH_mesh2d")
        if args.baseline_mesh2d:
            with open(args.baseline_mesh2d) as f:
                baseline_mesh2d = json.load(f)
            check_mesh2d_doc(baseline_mesh2d, "baseline BENCH_mesh2d")
            check_mesh2d_baseline_vs_fresh(baseline_mesh2d, fresh_mesh2d)
    if args.fresh_roundfuse:
        with open(args.fresh_roundfuse) as f:
            fresh_roundfuse = json.load(f)
        check_roundfuse_doc(fresh_roundfuse, "fresh BENCH_roundfuse")
        if args.baseline_roundfuse:
            with open(args.baseline_roundfuse) as f:
                baseline_roundfuse = json.load(f)
            check_roundfuse_doc(baseline_roundfuse,
                                "baseline BENCH_roundfuse")
            check_roundfuse_baseline_vs_fresh(baseline_roundfuse,
                                              fresh_roundfuse)
    print("[guard] all perf-regression checks passed")


if __name__ == "__main__":
    main()
