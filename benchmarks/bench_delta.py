"""Delta-parameterized state: store bytes, bit-identity, batched serving.

Three sections, all against repro.core.delta (base + per-agent delta
parameterization of the flat (n, D) buffer):

* **store rows** — host-store bytes of :class:`repro.core.delta.DeltaStore`
  vs the dense population store at n_total ∈ {1e4, 1e5, 1e6}, D = 2048,
  for ``topk:128`` / ``lowrank:8`` / ``full``.  Every row carries the exact
  analytic columns of ``launch.analysis.delta_cost_model``; rows small
  enough to materialize also record the *measured* ``DeltaStore.nbytes``
  (which must equal the model exactly — the guard checks) plus cohort
  gather/scatter µs.  The acceptance column is ``store_ratio`` ≤ 0.25 for
  the topk store at the largest n_total: 128·(4+4) = 1 KiB/agent vs the
  8 KiB dense row.
* **equivalence** — the delta engine at rank=full is **bit-identical** to
  the flat engine (``max_abs_err == 0.0``, pinned — the PR 4/5/6 gate).
  The full codec's two-term payload (p = fl(x−base), c = fl(x−fl(base+p)))
  round-trips bitwise, so the EF residual stays exactly zero and the
  gossip reduces to the uncompressed mix.  Also pins the DeltaStore
  full-kind gather∘scatter round-trip (same op order as the codec).
* **serving** — multi-tenant personalized decode
  (``launch.serve.generate_personalized``: gather deltas → one vmapped
  apply → ONE compiled dispatch per token for the whole batch) vs the
  naive baseline (B sequential ``generate`` calls, each with its own full
  parameter set).  Tokens/sec for both; the batched path must win and the
  decoded tokens must match the naive loop exactly.

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_delta.json (smoke runs write
BENCH_delta.smoke.json so the committed baseline is never clobbered).

Run:  PYTHONPATH=src python -m benchmarks.bench_delta [--smoke]
"""

from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.configs import get_config
from repro.core import delta as delta_lib
from repro.core import feddec, flat as flat_lib
from repro.core import topology as topo
from repro.core.mixing import MixingDistribution
from repro.data import linreg
from repro.launch import analysis, serve
from repro.models import build_model

STORE_D = 2048                      # dense row = 8 KiB at f32
STORE_DELTAS = ("topk:128", "lowrank:8", "full")
COHORT = 256                        # gather/scatter cohort for timing
# full materializes 2 (n, D) memmaps and lowrank runs an n-batched SVD on
# scatter — materialize those only at the smallest n; the O(n·K) topk store
# (the row the 0.25x acceptance is about) is cheap enough to materialize
# at every grid point, 1e6 included (~1 GiB on disk)
MATERIALIZE_CAP = {"topk": 10**6, "lowrank": 10**4, "full": 10**4}


def bench_store(n_total: int, delta: str, *, time_iters: int) -> dict:
    """One (n_total, delta) row: exact cost model + measured store."""
    model = analysis.delta_cost_model(n_total=n_total, d=STORE_D, delta=delta)
    spec = delta_lib.parse_delta(delta)
    row = {**model, "materialized": False, "measured_store_bytes": None,
           "gather_us": None, "scatter_us": None}
    if n_total <= MATERIALIZE_CAP[spec.kind]:
        rng = np.random.default_rng(0)
        base = rng.standard_normal(STORE_D).astype(np.float32)
        store = delta_lib.DeltaStore.create(n_total, base, spec)
        ids = rng.choice(n_total, size=COHORT, replace=False)
        vals = (base[None, :]
                + 0.01 * rng.standard_normal((COHORT, STORE_D))
                ).astype(np.float32)
        store.scatter(ids, vals)        # warm (page in the touched rows)
        store.gather(ids)
        ts_g, ts_s = [], []
        for _ in range(time_iters):
            t0 = time.perf_counter()
            store.scatter(ids, vals)
            ts_s.append(time.perf_counter() - t0)
            t0 = time.perf_counter()
            store.gather(ids)
            ts_g.append(time.perf_counter() - t0)
        row.update(materialized=True,
                   measured_store_bytes=store.nbytes,
                   gather_us=round(sorted(ts_g)[len(ts_g) // 2] * 1e6, 1),
                   scatter_us=round(sorted(ts_s)[len(ts_s) // 2] * 1e6, 1))
        del store
    common.emit(f"delta_store_{delta.replace(':', '')}_n{n_total}",
                row["gather_us"] or 0.0,
                f"store_ratio={model['store_ratio']:.4f};"
                f"materialized={row['materialized']}")
    return row


def bench_equivalence(*, rounds: int = 6) -> dict:
    """delta='full' trajectory ≡ the flat engine, bitwise (the PR-4 gate)."""
    n, d, h = 8, 25, 4
    problem = linreg.make_problem(n=n, m_rows=10, d=d, seed=0)
    graph = topo.geographic_graph(n, 0.5, seed=1)
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    lr = lambda t: jnp.float32(1e-3)  # noqa: E731
    key = jax.random.key(7)
    x0 = jax.random.normal(jax.random.key(11), (d,)) * 0.3
    per_round = [
        jax.block_until_ready(jax.vmap(
            lambda k: linreg.sample_minibatch(problem, k, m=2))(
            jax.random.split(jax.random.fold_in(jax.random.key(3), r), h)))
        for r in range(rounds)]

    def run(delta: str):
        cfg = feddec.FedDecConfig(
            mixing=MixingDistribution(graph, p_fail=0.0, scheme="metropolis"),
            h=h, k=3, gossip_impl="dense", delta=delta)
        fspec = flat_lib.make_flat_spec(jnp.zeros(d))
        base = fspec.ravel(x0) if delta != "none" else None
        rnd = flat_lib.make_flat_feddec_round(cfg, fspec, grad_fn, lr,
                                              donate=False, delta_base=base)
        st = flat_lib.init_flat_state(fspec, x0, n, delta=delta)
        for r in range(rounds):
            st, _ = rnd(st, per_round[r], key)
        res = 0.0 if isinstance(st.residual, tuple) \
            else float(jnp.abs(st.residual).max())
        return np.asarray(st.flat), res

    ref, _ = run("none")
    got, res_max = run("full")
    max_err = float(np.abs(got - ref).max())
    bit = bool(np.array_equal(got, ref))

    # DeltaStore full-kind round-trip: gather(scatter(x)) == x bitwise,
    # including adversarial magnitudes (the Sterbenz argument end-to-end)
    rng = np.random.default_rng(2)
    rows = rng.standard_normal((16, 64)).astype(np.float32)
    rows[0, :4] = [1e30, 1e-30, 1.2e-38, 0.0]
    store = delta_lib.DeltaStore.create(
        16, rng.standard_normal(64).astype(np.float32), "full")
    store.scatter(np.arange(16), rows)
    store_exact = bool(np.array_equal(store.gather(np.arange(16)), rows))

    common.emit("delta_equivalence", 0.0,
                f"max_abs_err={max_err:.1e};bit_identical={bit};"
                f"residual_max={res_max:.1e};store_roundtrip={store_exact}")
    return {"n_agents": n, "d": d, "h": h, "rounds": rounds,
            "max_abs_err": max_err, "bit_identical": bit,
            "residual_max_abs": res_max,
            "store_roundtrip_exact": store_exact}


def bench_serving(*, batch: int, prompt_len: int, new_tokens: int,
                  time_iters: int) -> dict:
    """Batched personalized decode vs B sequential full-weight generates."""
    cfg = get_config("qwen1.5-4b").smoke()
    model = build_model(cfg)
    params = model.init(jax.random.key(0))
    fspec = flat_lib.make_flat_spec(params)
    base = fspec.ravel(params)
    deltas = (jax.random.normal(jax.random.key(1), (batch, fspec.d))
              * 0.01).astype(base.dtype)
    prompt = jax.random.randint(jax.random.key(2), (batch, prompt_len), 0,
                                cfg.vocab_size)

    def run_batched():
        return serve.generate_personalized(
            model, fspec, base, deltas, prompt, max_new_tokens=new_tokens)

    def run_naive():
        outs = []
        for b in range(batch):
            p_b = fspec.unravel(base + deltas[b])
            outs.append(serve.generate(model, p_b, prompt[b:b + 1],
                                       max_new_tokens=new_tokens))
        return jnp.concatenate(outs, axis=0)

    got_b = jax.block_until_ready(run_batched())     # compile + warm
    got_n = jax.block_until_ready(run_naive())
    matches = bool(np.array_equal(np.asarray(got_b), np.asarray(got_n)))

    def med_tok_s(fn):
        ts = []
        for _ in range(time_iters):
            t0 = time.perf_counter()
            jax.block_until_ready(fn())
            ts.append(time.perf_counter() - t0)
        return batch * new_tokens / sorted(ts)[len(ts) // 2]

    batched_tok_s = med_tok_s(run_batched)
    naive_tok_s = med_tok_s(run_naive)
    speedup = batched_tok_s / naive_tok_s
    common.emit(f"delta_serving_b{batch}",
                batch * new_tokens / batched_tok_s * 1e6,
                f"batched_tok_s={batched_tok_s:.1f};"
                f"naive_tok_s={naive_tok_s:.1f};speedup={speedup:.2f}x;"
                f"matches_naive={matches}")
    return {"arch": cfg.name, "d_flat": int(fspec.d), "batch": batch,
            "prompt_len": prompt_len, "new_tokens": new_tokens,
            "batched_tok_s": round(batched_tok_s, 2),
            "naive_tok_s": round(naive_tok_s, 2),
            "speedup": round(speedup, 3), "matches_naive": matches}


def main(smoke: bool = False) -> None:
    if smoke:
        grid, iters = (10**4, 10**5), 3
        serving = bench_serving(batch=4, prompt_len=2, new_tokens=4,
                                time_iters=3)
    else:
        # batch pinned where the stacked (B, D) parameter working set still
        # fits this host's LLC — past that the one-dispatch win inverts on
        # CPU (B=6 already thrashes); accelerator memory moves the knee
        grid, iters = (10**4, 10**5, 10**6), 5
        serving = bench_serving(batch=4, prompt_len=4, new_tokens=16,
                                time_iters=5)

    rows = [bench_store(n, delta, time_iters=iters)
            for n in grid for delta in STORE_DELTAS]
    equivalence = bench_equivalence()

    max_n = max(grid)
    topk_at_max = next(r for r in rows
                       if r["n_total"] == max_n
                       and r["delta"].startswith("topk:"))
    acceptance = {
        "rank_full_bit_identical": equivalence["bit_identical"],
        "max_abs_err": equivalence["max_abs_err"],
        "residual_max_abs": equivalence["residual_max_abs"],
        "store_roundtrip_exact": equivalence["store_roundtrip_exact"],
        "max_n_total": max_n,
        "store_ratio_at_max_n": topk_at_max["store_ratio"],
        "batched_tok_s": serving["batched_tok_s"],
        "naive_tok_s": serving["naive_tok_s"],
        "serving_speedup": serving["speedup"],
        "serving_matches_naive": serving["matches_naive"],
        "note": ("bit-identity: the full codec's two-term payload "
                 "round-trips bitwise, the EF residual stays exactly zero, "
                 "and the gossip reduces to the uncompressed mix — "
                 "max_abs_err is pinned at 0.0; store_ratio_at_max_n is "
                 "the topk:128 DeltaStore vs the dense (n_total, 2048) "
                 "population store (<= 0.25 acceptance); serving compares "
                 "one vmapped dispatch per token against B sequential "
                 "full-weight generate calls decoding identical tokens")}
    out = {"workload": "delta-parameterized FedDec state "
                       "(store/engine/serving)",
           "backend": jax.default_backend(), "smoke": smoke,
           "rows": rows, "equivalence": equivalence, "serving": serving,
           "acceptance": acceptance}
    name = "BENCH_delta.smoke.json" if smoke else "BENCH_delta.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv("bench_delta.csv", list(rows[0].keys()),
                     [tuple(r.values()) for r in rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="smaller n_total grid / shorter serving run for CI")
    args = p.parse_args()
    print("name,us_per_call,derived")
    main(smoke=args.smoke)
