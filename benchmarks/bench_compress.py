"""Compressed-gossip subsystem benchmark (repro.core.compress).

Four sections, one JSON:

  1. **flat** — the whole-buffer EF gossip (encode → mix → diag-correct →
     residual) per compressor on one device: wall-clock plus the analytic
     per-row wire-payload bytes (`analysis.compress_row_bytes`).  The
     identity compressor is asserted bit-identical to the uncompressed mix.
  2. **halo** — the sharded engine's compressed ppermute halo
     (`sharded.make_sharded_ef_gossip`, 2/8 forced host devices): the
     encoded payload (int8 + scales / top-k values + indices / bf16) is
     what moves, so per-device collective bytes follow
     `analysis.compressed_halo_cost_model` — int8 ≈ 0.25× the f32 halo,
     the column CI's regression guard pins at ≤ 0.30.  Every timed config
     is first checked against the single-device EF gossip.
  3. **kernel** — the fused quantize→mix→dequantize Pallas kernels
     (kernels/compress_mix.py) vs the unfused XLA composition: off-TPU the
     kernels run in interpret mode, so the transferable evidence is the
     streamed-bytes model (fused receive side: q at 1 B/elem + p + y =
     9·nD vs the unfused 17·nD that materialises the f32 dequantized
     buffer), with correctness asserted against the XLA codec.
  4. **convergence** — the paper's linreg problem (fig4-style, fused flat
     rounds): int8+EF and bf16 must track the uncompressed trajectory
     (final running-mean loss within 5%); top-k trails but converges.

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_compress.json (consumed by CI's perf-regression
guard and docs/PERFORMANCE.md).  Smoke runs write BENCH_compress.smoke.json
so the committed baseline is never clobbered.

Run:  PYTHONPATH=src python -m benchmarks.bench_compress [--smoke]

Re-executes itself in a forced-8-device subprocess (same isolation pattern
as bench_sharded.py).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

N_DEVICES = 8

SCHEMES = ("none", "identity", "bf16", "int8", "topk:0.1")
HALO_SCHEMES = ("none", "bf16", "int8", "topk:0.1")


def kernel_stream_bytes(kind: str, n: int, d: int) -> float:
    """Analytic HBM bytes streamed per call by each kernel path
    (the column the regression guard re-derives):

      f32_mix             read x(4) + write y(4)                 =  8·nD
      fused_dequant_mix   read q(1) + read p(4) + write y(4)     =  9·nD
      xla_dequant_mix     dequant: read q(1) + write s(4);
                          mix: read s(4) + read p(4) + write y(4) = 17·nD
      fused_quant_mix     read u(4)+noise(4)+p(4), write y(4)+q(1) = 17·nD
                          (send side: the win is vs quantize + dequant +
                          mix as separate passes, not vs the receive side)
    """
    per_elem = {"f32_mix": 8.0, "fused_dequant_mix": 9.0,
                "xla_dequant_mix": 17.0, "fused_quant_mix": 17.0}[kind]
    return per_elem * n * d


def main(smoke: bool = False) -> None:
    """Respawn into a forced-8-device subprocess and stream its output."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={N_DEVICES} "
                        + env.get("XLA_FLAGS", "")).strip()
    env.setdefault("PYTHONPATH", os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    cmd = [sys.executable, "-m", "benchmarks.bench_compress", "--child"]
    if smoke:
        cmd.append("--smoke")
    res = subprocess.run(cmd, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError(f"bench_compress child failed ({res.returncode})")


def _child_main(smoke: bool) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks import common
    from repro.core import compress as compress_lib
    from repro.core import flat as flat_lib
    from repro.core import sharded, theory, topology as topo
    from repro.core.feddec import FedDecConfig
    from repro.core.mixing import MixingDistribution
    from repro.data import linreg
    from repro.kernels import ops as kernel_ops
    from repro.launch import analysis
    from repro.launch.mesh import make_agent_mesh

    assert len(jax.devices()) >= N_DEVICES, "forced host devices missing"

    if smoke:
        warmup, iters = 1, 3
        d = 1 << 12
        d_kernel = 1 << 12
        t_conv = 160
    else:
        warmup, iters = 2, 5
        d = 1 << 16
        d_kernel = 1 << 15
        t_conv = 600
    n = 32

    graph = topo.ring_graph(n, k=2)
    md = MixingDistribution(graph, scheme="metropolis")
    w = jnp.asarray(md.sample(jax.random.key(0)))
    p_host = jax.random.normal(jax.random.key(1), (n, d), jnp.float32)
    res0 = jnp.zeros((n, d), jnp.float32)
    key_c = jax.random.key(7)

    def dense_mix(w, s):
        return jnp.einsum("ij,jd->id", w, s,
                          precision=jax.lax.Precision.HIGHEST)

    # -- 1. flat whole-buffer EF gossip ------------------------------------
    rows = []
    base_out = np.asarray(jax.jit(dense_mix)(w, p_host))
    flat_out = {}
    for scheme in SCHEMES:
        comp = compress_lib.parse_compress(scheme)
        if comp is None:
            fn = jax.jit(lambda w, p, r, k: (dense_mix(w, p), r))
        else:
            fn = jax.jit(compress_lib.make_flat_ef_gossip(comp, dense_mix, n))
        y, _ = fn(w, p_host, res0, key_c)
        flat_out[scheme] = np.asarray(y)
        us = common.time_fn(fn, w, p_host, res0, key_c,
                            warmup=warmup, iters=iters)
        row_bytes = analysis.compress_row_bytes(scheme, d, 4)
        rows.append({"section": "flat", "compress": scheme, "n_agents": n,
                     "d": d, "us_per_call": round(us, 1),
                     "row_payload_bytes": row_bytes})
        common.emit(f"compress_flat_{scheme}_n{n}_d{d}", us,
                    f"row_bytes={row_bytes:.0f}")
    np.testing.assert_array_equal(flat_out["identity"], base_out)
    np.testing.assert_array_equal(flat_out["none"], base_out)

    # -- 2. sharded compressed ppermute halo -------------------------------
    halo_rows = []
    for n_shards in (2, N_DEVICES):
        cut = sharded.cut_edge_stats(graph, n_shards)
        halo_model = analysis.compressed_halo_cost_model(
            n_agents=n, d=d, n_shards=n_shards,
            num_halo_rounds=cut["num_halo_rounds"], param_bytes=4,
            schemes=HALO_SCHEMES)
        mesh = make_agent_mesh(n_shards)
        p_sh = jax.device_put(p_host, NamedSharding(mesh, P("agents")))
        r_sh = jax.device_put(res0, NamedSharding(mesh, P("agents")))
        for scheme in HALO_SCHEMES:
            cfg = FedDecConfig(mixing=md, gossip_impl="sparse",
                               gossip_compress=scheme)
            fn = jax.jit(sharded.make_sharded_ef_gossip(cfg, mesh))
            y, _ = fn(w, p_sh, r_sh, key_c)
            np.testing.assert_allclose(np.asarray(y), flat_out[scheme],
                                       atol=1e-4, rtol=1e-4)
            us = common.time_fn(fn, w, p_sh, r_sh, key_c,
                                warmup=warmup, iters=iters)
            cm = halo_model[scheme]
            halo_rows.append({
                "section": "halo", "compress": scheme, "n_agents": n,
                "n_shards": n_shards, "d": d,
                "us_per_call": round(us, 1),
                "row_payload_bytes": cm["row_payload_bytes"],
                "collective_bytes": cm["collective_bytes"],
                "payload_ratio_vs_f32": cm["payload_ratio_vs_f32"],
                "num_halo_rounds": cut["num_halo_rounds"]})
            common.emit(
                f"compress_halo_{scheme}_n{n}_s{n_shards}", us,
                f"coll_bytes={cm['collective_bytes']:.0f};"
                f"ratio={cm['payload_ratio_vs_f32']:.3f}")

    # -- 3. fused Pallas kernels vs unfused XLA ----------------------------
    comp8 = compress_lib.parse_compress("int8")
    u = jax.random.normal(jax.random.key(2), (n, d_kernel), jnp.float32)
    p_k = jax.random.normal(jax.random.key(3), (n, d_kernel), jnp.float32)
    keys = jax.random.split(jax.random.key(4), n)
    scale = comp8.row_scale(u)
    noise = compress_lib._row_noise(keys, d_kernel)
    payload = comp8.encode(keys, u)
    q = payload["q"]

    def xla_dequant_mix(w, q, scale, p):
        s = q.astype(jnp.float32) * scale[:, None]
        return dense_mix(w, s) + jnp.diagonal(w)[:, None] * (p - s)

    kern_impls = {
        "f32_mix": (jax.jit(lambda: kernel_ops.gossip_mix(w, u)),),
        "fused_dequant_mix": (
            jax.jit(lambda: kernel_ops.dequant_mix(w, q, scale, p_k)),),
        "xla_dequant_mix": (
            jax.jit(lambda: xla_dequant_mix(w, q, scale, p_k)),),
        "fused_quant_mix": (
            jax.jit(lambda: kernel_ops.quant_mix(w, u, noise, p_k, scale)),),
    }
    # correctness: the receive-side fused kernel matches the XLA codec
    # composition; the fully-fused send side may flip borderline stochastic
    # roundings by one q-step (ulp differences under floor), so it is
    # checked to one step on a vanishing fraction of elements
    ref = np.asarray(kern_impls["xla_dequant_mix"][0]())
    np.testing.assert_allclose(
        np.asarray(kern_impls["fused_dequant_mix"][0]()), ref,
        atol=1e-4, rtol=1e-4)
    y_f, q_f = kern_impls["fused_quant_mix"][0]()
    dq = np.abs(np.asarray(q_f).astype(np.int32) -
                np.asarray(q).astype(np.int32))
    assert dq.max() <= 1 and (dq != 0).mean() < 1e-3, \
        (dq.max(), (dq != 0).mean())
    np.testing.assert_allclose(np.asarray(y_f), ref, atol=0.1)

    kernel_rows = []
    for name, (fn,) in kern_impls.items():
        us = common.time_fn(fn, warmup=warmup, iters=iters)
        mb = kernel_stream_bytes(name, n, d_kernel)
        kernel_rows.append({
            "section": "kernel", "impl": name, "n_agents": n, "d": d_kernel,
            "us_per_call": round(us, 1), "model_stream_bytes": mb,
            "interpret_mode": name.startswith("fused")
            and not kernel_ops.on_tpu()})
        common.emit(f"compress_kernel_{name}_n{n}_d{d_kernel}", us,
                    f"model_bytes={mb:.0f}")

    # -- 4. fig4-style linreg convergence ----------------------------------
    problem = linreg.make_problem(n=8, seed=0, c_base=1.3)
    g_small = topo.geographic_graph(problem.n, 0.6, seed=3)
    md_small = MixingDistribution(g_small, scheme="laplacian")
    h = 10
    lr = theory.paper_stepsize(
        problem.mu, theory.gamma(problem.l_smooth, problem.mu, h))
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    spec = flat_lib.make_flat_spec(jnp.zeros(problem.d))
    keys_b = jax.random.split(jax.random.key(11), t_conv)
    batches = jax.vmap(lambda k: linreg.sample_minibatch(problem, k, m=1))(
        keys_b)
    conv_rows = []
    final_loss = {}
    for scheme in ("none", "bf16", "int8", "topk:0.25"):
        cfg = FedDecConfig(mixing=md_small, h=h, k=2, gossip_impl="dense",
                           gossip_compress=scheme)
        round_fn = flat_lib.make_flat_feddec_round(
            cfg, spec, grad_fn, lr, donate=False,
            metrics_fn=lambda s: {
                "subopt": problem.suboptimality(spec.unflatten(s.flat))})
        state = flat_lib.init_flat_state(spec, jnp.zeros(problem.d),
                                         problem.n, compress=scheme)
        state, metrics = round_fn(state, batches, jax.random.key(5))
        losses = np.asarray(metrics["loss"])
        subopt = np.asarray(metrics["subopt"])
        tail = max(1, t_conv // 10)
        final_loss[scheme] = float(losses[-tail:].mean())
        conv_rows.append({
            "section": "convergence", "compress": scheme,
            "t_steps": t_conv, "h": h,
            "final_loss_tail_mean": final_loss[scheme],
            "final_subopt_tail_mean": float(subopt[-tail:].mean()),
            "loss_curve_sampled": [round(float(x), 6)
                                   for x in losses[::max(1, t_conv // 40)]]})
        common.emit(f"compress_linreg_{scheme}_t{t_conv}", 0.1,
                    f"final_loss={final_loss[scheme]:.6f}")

    int8_ratio = final_loss["int8"] / final_loss["none"]
    bf16_ratio = final_loss["bf16"] / final_loss["none"]
    big = [r for r in halo_rows if r["n_shards"] == N_DEVICES]

    def coll(scheme):
        return next(r["collective_bytes"] for r in big
                    if r["compress"] == scheme)

    acceptance = {
        "identity_bit_identical_to_uncompressed": True,
        "equivalence_checked_sharded_vs_flat": True,
        "int8_halo_ratio_vs_f32": round(coll("int8") / coll("none"), 4),
        "int8_halo_ratio_ok": coll("int8") / coll("none") <= 0.30,
        "kernel_fused_vs_unfused_model_bytes": round(
            kernel_stream_bytes("fused_dequant_mix", n, d_kernel)
            / kernel_stream_bytes("xla_dequant_mix", n, d_kernel), 3),
        "int8_final_loss_ratio": round(int8_ratio, 4),
        "bf16_final_loss_ratio": round(bf16_ratio, 4),
        "int8_tracks_uncompressed_within_5pct":
            bool(abs(int8_ratio - 1.0) <= 0.05),
        "note": ("CPU host devices: halo collectives run over loopback and "
                 "Pallas kernels in interpret mode, so wall-clock is not "
                 "ICI/TPU-representative; the transferable evidence is the "
                 "exact collective_bytes / row_payload_bytes / "
                 "model_stream_bytes columns "
                 "(analysis.compress_row_bytes & compressed_halo_cost_model "
                 "at TPU constants) plus the s8 ppermute payloads visible "
                 "in the compiled HLO (tests/test_compress.py)"),
    }
    out = {"workload": "compressed gossip: EF codecs on the flat buffer, "
                       "compressed ppermute halo on the sharded engine, "
                       "fused quant/dequant-mix Pallas kernels, linreg "
                       "convergence",
           "backend": jax.default_backend(), "smoke": smoke,
           "devices": N_DEVICES,
           "rows": rows + halo_rows + kernel_rows,
           "convergence_rows": conv_rows,
           "acceptance": acceptance}
    name = "BENCH_compress.smoke.json" if smoke else "BENCH_compress.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv(
        "bench_compress.csv",
        ["section", "compress_or_impl", "n_agents", "n_shards", "d",
         "us_per_call", "bytes_column"],
        [(r["section"], r.get("compress", r.get("impl")), r["n_agents"],
          r.get("n_shards", 1), r["d"], r["us_per_call"],
          r.get("collective_bytes",
                r.get("model_stream_bytes", r.get("row_payload_bytes"))))
         for r in rows + halo_rows + kernel_rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / few iterations for CI")
    p.add_argument("--child", action="store_true",
                   help="internal: run the benchmark body (assumes the "
                        "forced-device XLA flag is already set)")
    args = p.parse_args()
    if args.child:
        _child_main(smoke=args.smoke)
    else:
        print("name,us_per_call,derived")
        main(smoke=args.smoke)
