"""Weak-scaling of the agent-sharded flat engine (repro.core.sharded).

The sharded engine block-shards the flat (n_agents, D) buffer's agent dim
over a device mesh axis; this benchmark measures, on 1/2/4/8 forced host
devices (``XLA_FLAGS=--xla_force_host_platform_device_count=8`` — the same
CPU recipe the multi-device CI job uses), at fixed D across
n_agents ∈ {8, 32, 128}:

  * ``dense``  — per-shard W[:, cols] @ x_blk + psum_scatter: collective
    bytes grow with n regardless of the graph;
  * ``sparse`` — the ppermute halo exchange over the ring graph's cut
    edges: 2 halo rounds per step at *any* n (the quotient of a ring over
    contiguous blocks is a ring), so per-device collective bytes stay flat
    as agents are added with devices — the weak-scaling win.

Every row carries measured wall-clock AND the analytic cost model
(launch.analysis.sharded_gossip_cost_model): on this CPU container the
collectives run over the host-platform loopback, so wall-clock ratios are
not ICI-representative — the transferable evidence is the per-device /
collective-byte columns and the cut-edge counts (cut_edge_stats).  Each
timed configuration is first checked against the unsharded dense einsum.

A second section times the full fused sharded round (H steps in one
shard_map'd lax.scan) on a quadratic workload, 1 vs 8 shards.

Emits the standard ``name,us_per_call,derived`` CSV lines plus
results/benchmarks/BENCH_sharded.json (consumed by CI's perf-regression
guard and docs/PERFORMANCE.md).

Run:  PYTHONPATH=src python -m benchmarks.bench_sharded [--smoke]

The benchmark re-executes itself in a subprocess with the forced-device-count
XLA flag so the parent process's jax device state is never touched (same
isolation pattern as tests/test_gossip_impls.py).
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys

N_DEVICES = 8


def main(smoke: bool = False) -> None:
    """Respawn into a forced-8-device subprocess and stream its output."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={N_DEVICES} "
                        + env.get("XLA_FLAGS", "")).strip()
    env.setdefault("PYTHONPATH", os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")))
    cmd = [sys.executable, "-m", "benchmarks.bench_sharded", "--child"]
    if smoke:
        cmd.append("--smoke")
    res = subprocess.run(cmd, env=env,
                         cwd=os.path.join(os.path.dirname(__file__), ".."))
    if res.returncode != 0:
        raise RuntimeError(f"bench_sharded child failed ({res.returncode})")


def _child_main(smoke: bool) -> None:
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from benchmarks import common
    from repro.core import flat as flat_lib
    from repro.core import sharded, topology as topo
    from repro.core.feddec import FedDecConfig
    from repro.core.mixing import MixingDistribution
    from repro.launch import analysis
    from repro.launch.mesh import make_agent_mesh

    assert len(jax.devices()) >= N_DEVICES, "forced host devices missing"

    if smoke:
        warmup, iters = 1, 3
        d = 1 << 12
        agent_grid = (8, 32)
        round_cfg = dict(n=32, h=4)
    else:
        warmup, iters = 2, 5
        d = 1 << 16
        agent_grid = (8, 32, 128)
        round_cfg = dict(n=32, h=8)
    shard_grid = (1, 2, 4, 8)

    rows = []
    for n in agent_grid:
        graph = topo.ring_graph(n, k=2)
        md = MixingDistribution(graph, scheme="metropolis")
        w = jnp.asarray(md.sample(jax.random.key(0)))
        x_host = jax.random.normal(jax.random.key(1), (n, d), jnp.float32)
        ref = np.asarray(jnp.einsum(
            "ij,jd->id", w, x_host, precision=jax.lax.Precision.HIGHEST))
        for n_shards in shard_grid:
            if n % n_shards:
                continue
            mesh = make_agent_mesh(n_shards)
            x = jax.device_put(x_host, NamedSharding(mesh, P("agents")))
            cut = sharded.cut_edge_stats(graph, n_shards)
            model = analysis.sharded_gossip_cost_model(
                n_agents=n, d=d, n_shards=n_shards,
                num_cut_edges=cut["num_cut_edges"],
                num_halo_rounds=cut["num_halo_rounds"], param_bytes=4)
            for impl in ("dense", "sparse"):
                cfg = FedDecConfig(mixing=md, gossip_impl=impl)
                fn = jax.jit(sharded.make_sharded_gossip(cfg, mesh))
                np.testing.assert_allclose(np.asarray(fn(w, x)), ref,
                                           atol=1e-4, rtol=1e-4)
                us = common.time_fn(fn, w, x, warmup=warmup, iters=iters)
                cm = model[impl]
                row = {"impl": impl, "n_agents": n, "n_shards": n_shards,
                       "agents_per_device": n // n_shards, "d": d,
                       "us_per_call": round(us, 1),
                       "per_device_bytes": cm["per_device_bytes"],
                       "collective_bytes": cm["collective_bytes"],
                       "num_cut_edges": cut["num_cut_edges"],
                       "num_halo_rounds": cut["num_halo_rounds"]}
                rows.append(row)
                common.emit(
                    f"sharded_gossip_{impl}_n{n}_s{n_shards}", us,
                    f"coll_bytes={cm['collective_bytes']:.0f};"
                    f"cut={cut['num_cut_edges']}")

    # full fused round: H steps of grad + gossip + server in one shard_map
    n, h = round_cfg["n"], round_cfg["h"]
    graph = topo.ring_graph(n, k=2)
    md = MixingDistribution(graph, scheme="metropolis")
    spec = flat_lib.make_flat_spec(jnp.zeros(d))

    def grad_fn(p, batch, key):
        del key
        return 0.5 * jnp.sum((p - batch) ** 2), p - batch

    def lr_fn(t):
        return jnp.asarray(0.05, jnp.float32)

    batches = jax.random.normal(jax.random.key(3), (h, n, d), jnp.float32)
    key = jax.random.key(4)
    round_rows = []
    for n_shards in (1, N_DEVICES):
        mesh = make_agent_mesh(n_shards)
        cfg = FedDecConfig(mixing=md, h=h, k=2, gossip_impl="sparse")
        round_fn = sharded.make_sharded_feddec_round(
            cfg, spec, grad_fn, lr_fn, mesh, donate=False)
        state = sharded.shard_flat_state(
            flat_lib.init_flat_state(spec, jnp.zeros(d), n), mesh)
        us = common.time_fn(lambda: round_fn(state, batches, key),
                            warmup=warmup, iters=iters)
        round_rows.append({"n_agents": n, "n_shards": n_shards, "d": d,
                           "h": h, "us_per_round": round(us, 1),
                           "us_per_step": round(us / h, 1)})
        common.emit(f"sharded_round_n{n}_s{n_shards}_h{h}", us,
                    f"per_step={us / h:.1f}us")

    def us_of(impl, n, s):
        return next(r["us_per_call"] for r in rows
                    if (r["impl"], r["n_agents"], r["n_shards"])
                    == (impl, n, s))

    n_big = agent_grid[-1]
    full_sparse = [r for r in rows if r["n_shards"] == N_DEVICES
                   and r["impl"] == "sparse"]
    full_dense = {r["n_agents"]: r for r in rows if r["n_shards"] == N_DEVICES
                  and r["impl"] == "dense"}
    acceptance = {
        "weak_scaling_sparse_8dev": [
            {"n_agents": r["n_agents"],
             "collective_bytes_per_device": r["collective_bytes"],
             "us_per_call": r["us_per_call"]} for r in full_sparse],
        # the sharding story, per n at the full device count: the ring's
        # halo is 2 block rounds once agents_per_device ≥ 2 (the k=2 ring
        # quotients to a plain ring over blocks), so sparse collective
        # bytes per device are ~2/(s−1) of the dense psum_scatter's
        "halo_rounds_8dev": {str(r["n_agents"]): r["num_halo_rounds"]
                             for r in full_sparse},
        "collective_ratio_sparse_over_dense_8dev": {
            str(r["n_agents"]):
                round(r["collective_bytes"]
                      / full_dense[r["n_agents"]]["collective_bytes"], 3)
            for r in full_sparse},
        "speedup_sparse_over_dense_at_n_big":
            round(us_of("dense", n_big, N_DEVICES)
                  / us_of("sparse", n_big, N_DEVICES), 2),
        "equivalence_checked_vs_unsharded_dense": True,
        "note": ("CPU host-platform devices: collectives run over loopback "
                 "memory, so wall-clock is not ICI-representative; the "
                 "transferable evidence is collective_bytes / num_cut_edges "
                 "(analysis.sharded_gossip_cost_model at TPU constants) and "
                 "the 2/(s-1) sparse-over-dense collective-byte ratio once "
                 "agents_per_device >= 2"),
    }
    out = {"workload": "agent-sharded gossip y = W @ x, (n, D) buffer "
                       "block-sharded over the 'agents' mesh axis",
           "backend": jax.default_backend(), "smoke": smoke,
           "devices": N_DEVICES, "rows": rows, "round_rows": round_rows,
           "acceptance": acceptance}
    # smoke runs get their own file so a local/CI --smoke never clobbers
    # the committed full-run baseline the regression guard diffs against
    name = "BENCH_sharded.smoke.json" if smoke else "BENCH_sharded.json"
    path = os.path.join(common.ensure_results_dir(), name)
    with open(path, "w") as f:
        json.dump(out, f, indent=2)
    print(f"# wrote {path}")
    common.write_csv("bench_sharded.csv", list(rows[0].keys()),
                     [tuple(r.values()) for r in rows])


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--smoke", action="store_true",
                   help="tiny shapes / few iterations for CI")
    p.add_argument("--child", action="store_true",
                   help="internal: run the benchmark body (assumes the "
                        "forced-device XLA flag is already set)")
    args = p.parse_args()
    if args.child:
        _child_main(smoke=args.smoke)
    else:
        print("name,us_per_call,derived")
        main(smoke=args.smoke)
