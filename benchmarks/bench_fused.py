"""Fused round executor vs per-step dispatch — the H-sweep cost model.

FedDec's key experimental axis is H, the number of local/gossip steps between
server rounds (Fig. 4 sweeps H ∈ {10, 100}).  The per-step executor pays one
Python dispatch + host-device sync per iteration, so an H-sweep costs O(H)
fixed overhead per round; the fused executor (core.feddec.make_feddec_round)
runs the whole window inside one compiled ``lax.scan`` and pays it once.

This benchmark times both executors on the paper's linear-regression workload
across H ∈ {10, 100} × n_agents ∈ {8, 16, 32} and emits the standard
``name,us_per_call,derived`` CSV (one row per configuration, us_per_call =
fused wall-clock per *round*), plus a full table under results/benchmarks/.

Run:  PYTHONPATH=src python -m benchmarks.bench_fused [--quick]
"""

from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp

from benchmarks import common
from repro.core import feddec, theory, topology as topo
from repro.core.mixing import MixingDistribution
from repro.data import linreg

H_VALUES = (10, 100)
N_AGENTS = (8, 16, 32)
K = 2


def _make_executors(problem: linreg.LinRegProblem, h: int):
    graph = topo.geographic_graph(problem.n, 0.6, seed=1)
    mixing = MixingDistribution(graph, scheme="laplacian")
    fcfg = feddec.FedDecConfig(mixing=mixing, h=h, k=K)
    lr = theory.paper_stepsize(
        problem.mu, theory.gamma(problem.l_smooth, problem.mu, h))
    grad_fn = linreg.make_grad_fn(problem.m_rows)
    # donate=False so the timing loop can reuse the same state buffers
    step = feddec.make_feddec_step(fcfg, grad_fn, lr, donate=False)
    round_fn = feddec.make_feddec_round(fcfg, grad_fn, lr, donate=False)
    return step, round_fn


def _batches(problem: linreg.LinRegProblem, h: int, m: int = 1):
    keys = jax.random.split(jax.random.key(3), h)
    return jax.vmap(lambda k: linreg.sample_minibatch(problem, k, m=m))(keys)


def bench_one(n: int, h: int, *, warmup: int, iters: int):
    """Returns (us_fused_per_round, us_per_step_per_round)."""
    problem = linreg.make_problem(n=n, seed=0, c_base=1.5)
    step, round_fn = _make_executors(problem, h)
    state = feddec.init_state(jnp.zeros(problem.d), n)
    batches = _batches(problem, h)
    key = jax.random.key(7)

    def run_fused():
        return round_fn(state, batches, key)

    # pre-slice outside the timed region: the per-step baseline must pay
    # for dispatch + sync only, not for H batch-slicing gathers
    step_batches = [
        jax.block_until_ready(jax.tree.map(lambda x: x[t], batches))
        for t in range(h)]

    def run_per_step():
        s = state
        for b in step_batches:
            s, m = step(s, b, key)
        return s, m

    us_fused = common.time_fn(run_fused, warmup=warmup, iters=iters)
    us_steps = common.time_fn(run_per_step, warmup=warmup, iters=iters)
    return us_fused, us_steps


def main(quick: bool = False) -> None:
    warmup, iters = (1, 3) if quick else (2, 10)
    rows = []
    for n in N_AGENTS:
        for h in H_VALUES:
            us_fused, us_steps = bench_one(n, h, warmup=warmup, iters=iters)
            speedup = us_steps / us_fused
            rows.append((n, h, round(us_fused, 1), round(us_steps, 1),
                         round(speedup, 2)))
            common.emit(
                f"fused_round_n{n}_H{h}", us_fused,
                f"per_step_us={us_steps:.1f};speedup={speedup:.2f}x")
    common.write_csv("bench_fused.csv",
                     ["n_agents", "H", "fused_us_per_round",
                      "per_step_us_per_round", "speedup"], rows)


if __name__ == "__main__":
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--quick", "--smoke", dest="quick", action="store_true",
                   help="fewer timing iterations for CI (alias: --smoke)")
    args = p.parse_args()
    print("name,us_per_call,derived")
    main(quick=args.quick)
